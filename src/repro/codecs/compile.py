"""Codec compiler: lower ``Codec`` trees to fused kernel-backed programs.

The interpreted combinators (``Repeat``/``Serial``/``BBANS``/...) run
one ``ans.push``/``ans.pop`` per Python-level dispatch: every symbol
pays a full-stack scatter and a host dispatch. This module removes
that cost by *lowering* the tree (``_lower``): ``Repeat`` nodes are
probed - ``codec_fn(d)`` is called for every position - and when the
per-position leaves are a recognized family with stackable parameters
they collapse into one vectorized node:

  * ``Uniform`` / ``DiscretizedGaussian`` / ``DiscretizedLogistic``
    -> ``_GridRepeat``: encode gathers all [n, lanes] (start, freq)
    pairs in one shot and makes a single multi-step
    ``kernels.ans.ops.push_many`` call; decode is one fused
    bucketize+pop kernel call (``ops.pop_many_grid`` - the CDF
    bisection of ``kernels/bucketize`` inside the ANS renorm chain).
  * ``Bernoulli`` / ``Categorical`` / ``BetaBinomial`` ->
    ``_TableRepeat``: per-step cumulative-starts tables, one
    ``push_many`` / ``pop_many_dyn`` (dynamic-table kernel) call.

Unrecognized or heterogeneous ``Repeat`` bodies (and plain leaves,
``FnCodec``s, ...) fall back to their interpreted form - still
correct, just not fused. Function-valued children (``BBANS``
likelihood/posterior, ``BitSwap`` layers) are lowered lazily at call
time, so closures over network outputs lower too.

**The determinism contract** (why there is no single whole-tree jit):
coding is only lossless if encoder and decoder compute bit-identical
fixed-point CDFs, and float32 results in XLA depend on the fusion
context - the same ``exp``/``ndtr`` chain fused into two different
programs can differ by one ulp, which flips a ``floor`` one time in
~10^4 and corrupts the stream. The compiler therefore keeps every
model-float evaluation (networks, CDF starts, tables) in *canonical
eager form* - bit-identical to the interpreted path by construction -
and fuses the **integer** coder loops into a handful of jitted
programs with donated ``ANSStack`` buffers (integer ops are exact in
any context). The Gaussian/logistic CDF chain is additionally written
in its XLA-canonical form (concrete edge tables, reciprocal-multiply
standardization - see ``core.discretize``), which makes the fused
in-kernel CDF inversion bit-stable too; ``tests/test_compile.py``
enforces all of this at scale. Wire bytes are **identical** to the
interpreted path.

Example::

    prog = codecs.compile(codecs.Chained(make_bb_codec(p, cfg), n))
    blob = codecs.compress(prog, data, lanes=16, seed=0)
    assert blob == codecs.compress(interpreted, data, lanes=16, seed=0)

Import note: ``codecs.compile`` (the function re-exported by
``repro.codecs``) shadows this module's dotted path; use
``from repro.codecs.compile import ...`` for the internals.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Any, Callable, Dict, Optional, Type

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ans, discretize
from repro.core.codec import Codec
from repro.core.distributions import (Bernoulli, BetaBinomial, Categorical,
                                      _stable_softmax,
                                      beta_binomial_log_pmf)
from repro.codecs import combinators as C
from repro.codecs import leaves as L
from repro.codecs import quantize as Q
from repro.kernels import dispatch
from repro.kernels.ans import ops as ans_ops


# ---------------------------------------------------------------------------
# jitted integer coder programs (shared across all compiled codecs)
# ---------------------------------------------------------------------------
# The ANSStack argument is donated in the True variants so encode and
# decode update the coder state in place; drivers never reuse an input
# stack, tests that do should compile with donate=False.
#
# ``backend`` is a ``kernels.dispatch.Decision`` (hashable -> a valid
# static arg): the fused nodes resolve it eagerly per call, so
# ``use_backend``/``REPRO_KERNEL_BACKEND``/the tuning cache steer even
# already-compiled codecs, at the cost of one retrace per distinct
# Decision.

def _coder_jits(fn, static):
    return {
        True: jax.jit(fn, static_argnames=static, donate_argnums=(0,)),
        False: jax.jit(fn, static_argnames=static),
    }


def _push_grid_body(stack, idxT, mu, sigma, *, kind, bits, precision,
                    backend=None):
    """Grid push with the starts evaluation INSIDE the jit.

    The eager-starts hop used to dominate compiled grid encode; the CDF
    chain is the canonical fusion-stable form (concrete edge tables,
    reciprocal-multiply - the decode side already evaluates it inside
    ``pop_many_grid``'s fused bisection), so tracing it here keeps the
    wire bytes identical while removing the host round-trip.
    """
    if kind == "uniform":
        shift = precision - bits
        start = idxT.astype(jnp.uint32) << shift
        freq = jnp.full_like(start, jnp.uint32(1 << shift))
    else:
        if kind == "gaussian":
            f = discretize.posterior_starts_fn(mu, sigma, bits, precision)
        else:
            f = L.logistic_starts_fn(mu, sigma, bits, precision)
        start = f(idxT)
        freq = f(idxT + 1) - start
    return ans_ops.push_many(stack, start[::-1], freq[::-1],
                             precision=precision, backend=backend)


def _push_table_body(stack, tables, symT, *, precision, backend=None):
    """Table push with the per-step starts gather INSIDE the jit
    (integer gather: exact in any fusion context)."""
    sym = symT[..., None]                                 # [n, lanes, 1]
    start = jnp.take_along_axis(tables, sym, axis=2)[..., 0]
    nxt = jnp.take_along_axis(tables, sym + 1, axis=2)[..., 0]
    return ans_ops.push_many(stack, start[::-1].astype(jnp.uint32),
                             (nxt - start)[::-1].astype(jnp.uint32),
                             precision=precision, backend=backend)


_PUSH_MANY = _coder_jits(ans_ops.push_many, ("precision", "backend"))
_POP_DYN = _coder_jits(ans_ops.pop_many_dyn, ("precision", "backend"))
_POP_GRID = _coder_jits(
    ans_ops.pop_many_grid,
    ("kind", "steps", "lat_bits", "precision", "backend"))
_PUSH_GRID = _coder_jits(
    _push_grid_body, ("kind", "bits", "precision", "backend"))
_PUSH_TABLE = _coder_jits(_push_table_body, ("precision", "backend"))


# ---------------------------------------------------------------------------
# mesh-sharded coder programs (lane-axis SPMD; see docs/SCALING.md)
# ---------------------------------------------------------------------------
# Under ``sharding.api.use_lane_mesh``, the fused nodes below swap the
# shared jits for shard_map-wrapped twins: one SPMD program per
# direction, the ANSStack lane axis (and every per-lane operand axis)
# split across the mesh. Integer coder ops are exact in any
# partitioning context, so the wire bytes are identical to the
# meshless path - the PR-4 determinism contract extends to devices.
# Programs are cached per mesh (the compiled executables are keyed by
# the device set, so two meshes over the same devices share nothing).

def _stack_spec(axis: str) -> ans.ANSStack:
    from jax.sharding import PartitionSpec as P
    return ans.ANSStack(head=P(axis), buf=P(axis, None), ptr=P(axis),
                        underflows=P(axis), overflows=P(axis))


def _mesh_coder_programs(mesh) -> Dict[str, Any]:
    """The shard_map'd twins of the three fused coder entry points."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    (axis,) = mesh.axis_names
    st = _stack_spec(axis)
    lane1 = P(None, axis)          # [steps, lanes]

    def push(stack, starts, freqs, *, precision, backend=None):
        return shard_map(
            lambda s, a, f: ans_ops.push_many(
                s, a, f, precision=precision, backend=backend),
            mesh=mesh, in_specs=(st, lane1, lane1), out_specs=st,
            check_rep=False)(stack, starts, freqs)

    def pop_dyn(stack, tables, *, precision, backend=None):
        return shard_map(
            lambda s, t: ans_ops.pop_many_dyn(
                s, t, precision=precision, backend=backend),
            mesh=mesh, in_specs=(st, P(None, axis, None)),
            out_specs=(st, lane1), check_rep=False)(stack, tables)

    def pop_grid(stack, *, mu, sigma, kind, steps, lat_bits, precision,
                 backend=None):
        spec = lane1 if jnp.ndim(mu) == 2 else P()
        return shard_map(
            lambda s, m, g: ans_ops.pop_many_grid(
                s, kind, m, g, steps, lat_bits, precision=precision,
                backend=backend),
            mesh=mesh, in_specs=(st, spec, spec),
            out_specs=(st, lane1), check_rep=False)(stack, mu, sigma)

    def push_grid(stack, idxT, mu, sigma, *, kind, bits, precision,
                  backend=None):
        spec = lane1 if jnp.ndim(mu) == 2 else P()
        return shard_map(
            lambda s, i, m, g: _push_grid_body(
                s, i, m, g, kind=kind, bits=bits, precision=precision,
                backend=backend),
            mesh=mesh, in_specs=(st, lane1, spec, spec), out_specs=st,
            check_rep=False)(stack, idxT, mu, sigma)

    def push_table(stack, tables, symT, *, precision, backend=None):
        return shard_map(
            lambda s, t, y: _push_table_body(
                s, t, y, precision=precision, backend=backend),
            mesh=mesh, in_specs=(st, P(None, axis, None), lane1),
            out_specs=st, check_rep=False)(stack, tables, symT)

    return {
        "push": _coder_jits(push, ("precision", "backend")),
        "pop_dyn": _coder_jits(pop_dyn, ("precision", "backend")),
        "pop_grid": _coder_jits(
            pop_grid,
            ("kind", "steps", "lat_bits", "precision", "backend")),
        "push_grid": _coder_jits(
            push_grid, ("kind", "bits", "precision", "backend")),
        "push_table": _coder_jits(push_table, ("precision", "backend")),
    }


#: program cache keyed by mesh: Mesh is hashable on (devices, axis
#: names), exactly the identity of the lowered SPMD executables.
_MESH_PROGRAMS: Dict[Any, Dict[str, Any]] = {}


def coder_programs(mesh: Optional[Any] = None) -> Dict[str, Any]:
    """The active coder programs: shared jits, or the ``mesh``-sharded
    twins (built once per mesh and cached).

    Example::

        progs = coder_programs(sharding.lane_mesh())
        stack = progs["push"][True](stack, starts, freqs, precision=16)
    """
    if mesh is None:
        return {"push": _PUSH_MANY, "pop_dyn": _POP_DYN,
                "pop_grid": _POP_GRID, "push_grid": _PUSH_GRID,
                "push_table": _PUSH_TABLE}
    if mesh not in _MESH_PROGRAMS:
        if len(mesh.axis_names) != 1:
            raise ValueError(
                f"codecs.compile: lane meshes are 1-D, got axes "
                f"{mesh.axis_names} (build one with sharding.lane_mesh)")
        _MESH_PROGRAMS[mesh] = _mesh_coder_programs(mesh)
    return _MESH_PROGRAMS[mesh]


def _active_programs() -> Dict[str, Any]:
    from repro.sharding import api as shard_api
    return coder_programs(shard_api.current_lane_mesh())


# ---------------------------------------------------------------------------
# vectorized Repeat nodes (the fused leaves of a lowered tree)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _GridRepeat(Codec):
    """A ``Repeat`` of max-entropy-grid leaves, fused.

    ``kind``: "uniform" (mu/sigma unused), "gaussian" (mu, sigma) or
    "logistic" (mu carries location, sigma the scale); parameters are
    [n, lanes] in natural position order. Bit-exact with the
    per-position ``Repeat``: push flips to the LIFO order (positions
    n-1..0), pop streams positions in natural order. The starts/freqs
    CDF chain is the canonical fusion-stable form, so both directions
    run as one jitted program each (starts evaluated in-jit - see
    ``_push_grid_body``) on the backend ``kernels.dispatch`` resolves
    per call.
    """

    kind: str
    mu: Optional[jnp.ndarray]
    sigma: Optional[jnp.ndarray]
    n: int
    bits: int
    precision: int
    out_dtype: Any = jnp.int32
    donate: bool = True

    def push(self, stack: ans.ANSStack, x: jnp.ndarray) -> ans.ANSStack:
        idx = x.astype(jnp.int32).T                       # [n, lanes]
        mu = self.mu if self.mu is not None else jnp.zeros(())
        sigma = self.sigma if self.sigma is not None else jnp.zeros(())
        d = dispatch.resolve("push_many", lanes=stack.lanes)
        return _active_programs()["push_grid"][self.donate](
            stack, idx, mu, sigma, kind=self.kind, bits=self.bits,
            precision=self.precision, backend=d)

    def pop(self, stack: ans.ANSStack):
        mu = self.mu if self.mu is not None else jnp.zeros(())
        sigma = self.sigma if self.sigma is not None else jnp.zeros(())
        d = dispatch.resolve("pop_many_grid", lanes=stack.lanes)
        stack, syms = _active_programs()["pop_grid"][self.donate](
            stack, mu=mu, sigma=sigma, kind=self.kind, steps=self.n,
            lat_bits=self.bits, precision=self.precision, backend=d)
        return stack, syms.T.astype(self.out_dtype)


@dataclasses.dataclass(frozen=True)
class _TableRepeat(Codec):
    """A ``Repeat`` of table-coded leaves, fused.

    ``tables``: uint32[n, lanes, A+1] per-position cumulative starts in
    natural order (built eagerly at lowering time - canonical bits);
    one dynamic multi-step program call each way, starts gathered
    in-jit (integer gather - see ``_push_table_body``).
    """

    tables: jnp.ndarray
    precision: int
    out_dtype: Any = jnp.int32
    donate: bool = True

    def push(self, stack: ans.ANSStack, x: jnp.ndarray) -> ans.ANSStack:
        symT = x.astype(jnp.int32).T                      # [n, lanes]
        d = dispatch.resolve("push_many_table", lanes=stack.lanes,
                             table_size=self.tables.shape[-1] - 1)
        return _active_programs()["push_table"][self.donate](
            stack, self.tables, symT, precision=self.precision,
            backend=d)

    def pop(self, stack: ans.ANSStack):
        d = dispatch.resolve("pop_many_dyn", lanes=stack.lanes,
                             table_size=self.tables.shape[-1] - 1)
        stack, syms = _active_programs()["pop_dyn"][self.donate](
            stack, self.tables, precision=self.precision, backend=d)
        return stack, syms.T.astype(self.out_dtype)


# ---------------------------------------------------------------------------
# fused fixed-point programs (model forward INSIDE the jit)
# ---------------------------------------------------------------------------
# When a BBANS/BitSwap tree's function-valued children are
# ``quantize.FixedPointFn`` markers, the whole combinator schedule -
# quantized network forward, CDF bucketize, ANS renorm - is traced into
# ONE jitted program per direction. The model math is integer/LUT
# (exact in any fusion context, see codecs/quantize.py) and the
# Gaussian CDF chain is the same canonical form the kernels already
# evaluate inside jit, so wire bytes are identical to the interpreted
# (eager) twin of the same quantized codec. The eager-float hop per
# Repeat step - the dominant cost of the lazy BBANS lowering below -
# disappears entirely.

def _traced_push_uniform(stack: ans.ANSStack, idxT: jnp.ndarray,
                         bits: int, precision: int,
                         backend=None) -> ans.ANSStack:
    shift = precision - bits
    start = idxT.astype(jnp.uint32) << shift
    freq = jnp.full_like(start, jnp.uint32(1 << shift))
    return ans_ops.push_many(stack, start[::-1], freq[::-1],
                             precision=precision, backend=backend)


def _traced_push_gaussian(stack: ans.ANSStack, idxT: jnp.ndarray,
                          muT: jnp.ndarray, sigmaT: jnp.ndarray,
                          bits: int, precision: int,
                          backend=None) -> ans.ANSStack:
    f = discretize.posterior_starts_fn(muT, sigmaT, bits, precision)
    start = f(idxT)
    freq = f(idxT + 1) - start
    return ans_ops.push_many(stack, start[::-1], freq[::-1],
                             precision=precision, backend=backend)


def _fp_push(stack: ans.ANSStack, fx: "Q.FixedPointFn", ctx: Any,
             sym: jnp.ndarray, backend=None) -> ans.ANSStack:
    """Push ``sym`` under the codec ``fx`` parameterizes by ``ctx``."""
    flat = sym.reshape(sym.shape[0], -1).astype(jnp.int32)
    if fx.family == "gaussian":
        mu, sigma = fx.params(ctx)
        return _traced_push_gaussian(stack, flat.T, mu.T, sigma.T,
                                     fx.bits, fx.precision, backend)
    f1 = fx.params(ctx).T.astype(jnp.uint32)          # [n, lanes]
    total = jnp.uint32(1 << fx.precision)
    f0 = total - f1
    is1 = flat.T.astype(bool)
    start = jnp.where(is1, f0, jnp.uint32(0))
    freq = jnp.where(is1, f1, f0)
    return ans_ops.push_many(stack, start[::-1], freq[::-1],
                             precision=fx.precision, backend=backend)


def _fp_pop(stack: ans.ANSStack, fx: "Q.FixedPointFn",
            ctx: Any, backend=None) -> tuple:
    """Pop a symbol under the codec ``fx`` parameterizes by ``ctx``."""
    if fx.family == "gaussian":
        mu, sigma = fx.params(ctx)
        stack, symT = ans_ops.pop_many_grid(
            stack, "gaussian", mu.T, sigma.T, fx.n, fx.bits,
            precision=fx.precision, backend=backend)
    else:
        f1 = fx.params(ctx).T.astype(jnp.uint32)      # [n, lanes]
        total = jnp.uint32(1 << fx.precision)
        tables = jnp.stack(
            [jnp.zeros_like(f1), total - f1, jnp.full_like(f1, total)],
            axis=-1)
        stack, symT = ans_ops.pop_many_dyn(stack, tables,
                                           precision=fx.precision,
                                           backend=backend)
    sym = symT.T
    if fx.shape:
        sym = sym.reshape((sym.shape[0],) + tuple(fx.shape))
    return stack, sym


class _FusedBBANS(Codec):
    """``BBANS`` with FixedPointFn children: one jit per direction.

    The push/pop bodies replay ``combinators.BBANS``'s exact schedule
    with the quantized model forward traced in-line and every
    multi-symbol leg on the fused kernels. ``push_body``/``pop_body``
    are the untraced schedules, reused by ``_FusedChained``'s scan.
    """

    def __init__(self, prior_bits: int, prior_precision: int,
                 posterior: "Q.FixedPointFn", likelihood: "Q.FixedPointFn",
                 donate: bool = True):
        n_lat = posterior.n

        def push_body(stack, s, backend=None):
            mu, sigma = posterior.params(s)
            stack, yT = ans_ops.pop_many_grid(
                stack, "gaussian", mu.T, sigma.T, n_lat, posterior.bits,
                precision=posterior.precision, backend=backend)
            stack = _fp_push(stack, likelihood, yT.T, s, backend)
            return _traced_push_uniform(stack, yT, prior_bits,
                                        prior_precision, backend)

        def pop_body(stack, backend=None):
            z = jnp.zeros(())
            stack, yT = ans_ops.pop_many_grid(
                stack, "uniform", z, z, n_lat, prior_bits,
                precision=prior_precision, backend=backend)
            stack, s = _fp_pop(stack, likelihood, yT.T, backend)
            mu, sigma = posterior.params(s)
            stack = _traced_push_gaussian(stack, yT, mu.T, sigma.T,
                                          posterior.bits,
                                          posterior.precision, backend)
            return stack, s

        self.push_body, self.pop_body = push_body, pop_body
        dn = (0,) if donate else ()
        self._push = jax.jit(push_body, donate_argnums=dn,
                             static_argnames=("backend",))
        self._pop = jax.jit(pop_body, donate_argnums=dn,
                            static_argnames=("backend",))

    def push(self, stack: ans.ANSStack, s: Any) -> ans.ANSStack:
        return self._push(stack, s,
                          backend=dispatch.resolve("push_many",
                                                   lanes=stack.lanes))

    def pop(self, stack: ans.ANSStack):
        return self._pop(stack,
                         backend=dispatch.resolve("pop_many_grid",
                                                  lanes=stack.lanes))


class _FusedBitSwap(Codec):
    """``BitSwap`` with FixedPointFn layers: one jit per direction."""

    def __init__(self, prior_bits: int, prior_precision: int, n_lat: int,
                 layers: tuple, donate: bool = True):
        def push_body(stack, s, backend=None):
            ctx = s
            for post_f, lik_f in layers:
                mu, sigma = post_f.params(ctx)
                stack, zT = ans_ops.pop_many_grid(
                    stack, "gaussian", mu.T, sigma.T, post_f.n,
                    post_f.bits, precision=post_f.precision,
                    backend=backend)
                stack = _fp_push(stack, lik_f, zT.T, ctx, backend)
                ctx = zT.T
            return _traced_push_uniform(stack, ctx.T, prior_bits,
                                        prior_precision, backend)

        def pop_body(stack, backend=None):
            zz = jnp.zeros(())
            stack, zT = ans_ops.pop_many_grid(
                stack, "uniform", zz, zz, n_lat, prior_bits,
                precision=prior_precision, backend=backend)
            z = zT.T
            for post_f, lik_f in reversed(layers):
                stack, ctx = _fp_pop(stack, lik_f, z, backend)
                mu, sigma = post_f.params(ctx)
                stack = _traced_push_gaussian(stack, z.T, mu.T, sigma.T,
                                              post_f.bits,
                                              post_f.precision, backend)
                z = ctx
            return stack, z

        self.push_body, self.pop_body = push_body, pop_body
        dn = (0,) if donate else ()
        self._push = jax.jit(push_body, donate_argnums=dn,
                             static_argnames=("backend",))
        self._pop = jax.jit(pop_body, donate_argnums=dn,
                            static_argnames=("backend",))

    def push(self, stack: ans.ANSStack, s: Any) -> ans.ANSStack:
        return self._push(stack, s,
                          backend=dispatch.resolve("push_many",
                                                   lanes=stack.lanes))

    def pop(self, stack: ans.ANSStack):
        return self._pop(stack,
                         backend=dispatch.resolve("pop_many_grid",
                                                  lanes=stack.lanes))


class _FusedChained(Codec):
    """``Chained`` over a fused fixed-point inner: the whole chain is a
    ``lax.scan`` of the inner's schedule - one jit for ALL datapoints.

    Safe here (and only here): the scan body is integer/LUT model math
    plus the canonical CDF chain, both bit-stable in any fusion
    context, so the per-datapoint bytes match the Python chain loop.
    """

    def __init__(self, inner: Codec, n: int, donate: bool = True):
        self.n = n
        inner_push, inner_pop = inner.push_body, inner.pop_body

        def push_body(stack, data, backend=None):
            def body(st, s):
                return inner_push(st, s, backend), None

            stack, _ = jax.lax.scan(body, stack, data)
            return stack

        def pop_body(stack, backend=None):
            def body(st, _):
                st, s = inner_pop(st, backend)
                return st, s

            stack, rev = jax.lax.scan(body, stack, None, length=n)
            return stack, jax.tree_util.tree_map(
                lambda x: jnp.flip(x, axis=0), rev)

        dn = (0,) if donate else ()
        self._push = jax.jit(push_body, donate_argnums=dn,
                             static_argnames=("backend",))
        self._pop = jax.jit(pop_body, donate_argnums=dn,
                            static_argnames=("backend",))

    def push(self, stack: ans.ANSStack, data: Any) -> ans.ANSStack:
        for leaf in jax.tree_util.tree_leaves(data):
            if leaf.shape[0] != self.n:
                raise ValueError(
                    f"Chained(n={self.n}): data leading axis is "
                    f"{leaf.shape[0]} - a mismatch would silently code "
                    "the wrong number of datapoints")
        return self._push(stack, data,
                          backend=dispatch.resolve("push_many",
                                                   lanes=stack.lanes))

    def pop(self, stack: ans.ANSStack):
        return self._pop(stack,
                         backend=dispatch.resolve("pop_many_grid",
                                                  lanes=stack.lanes))


def _uniform_prior_spec(prior: Codec, n_lat: int, donate: bool):
    """Lower a BBANS/BitSwap prior; accept only the uniform grid shape
    the fused schedules hard-code. Returns (bits, precision) or None."""
    if not isinstance(prior, C.Repeat):
        return None
    low = _lower_repeat(prior, donate)
    if not (isinstance(low, _GridRepeat) and low.kind == "uniform"
            and low.n == n_lat):
        return None
    return low.bits, low.precision


def _lower_fused_bbans(codec: C.BBANS, donate: bool) -> Optional[Codec]:
    post, lik = codec.posterior, codec.likelihood
    if not (isinstance(post, Q.FixedPointFn)
            and isinstance(lik, Q.FixedPointFn)):
        return None
    if post.family != "gaussian":
        return None
    spec = _uniform_prior_spec(codec.prior, post.n, donate)
    if spec is None:
        return None
    return _FusedBBANS(spec[0], spec[1], post, lik, donate)


def _lower_fused_bitswap(codec: C.BitSwap, donate: bool) -> Optional[Codec]:
    layers = codec.layers
    if not layers or not all(
            isinstance(p, Q.FixedPointFn) and isinstance(lk, Q.FixedPointFn)
            for p, lk in layers):
        return None
    if any(p.family != "gaussian" for p, _ in layers):
        return None
    n_lat = layers[-1][0].n
    spec = _uniform_prior_spec(codec.prior, n_lat, donate)
    if spec is None:
        return None
    return _FusedBitSwap(spec[0], spec[1], n_lat, layers, donate)


# ---------------------------------------------------------------------------
# lowering
# ---------------------------------------------------------------------------

def _same(vals) -> bool:
    return all(v == vals[0] for v in vals[1:])


#: leaf family -> (array param fields, static fields). Order matters:
#: most-derived classes first (isinstance is used, so e.g. the HVAE's
#: KernelDiscretizedGaussian lowers as a Gaussian).
_FAMILIES = (
    (L.Uniform, (), ("bits", "precision")),
    (L.DiscretizedGaussian, ("mu", "sigma"), ("bits", "precision")),
    (L.DiscretizedLogistic, ("mu", "scale"), ("bits", "precision")),
    (Bernoulli, ("logits",), ("precision",)),
    (BetaBinomial, ("alpha", "beta"), ("n", "precision")),
    (Categorical, ("logits",), ("precision",)),
)


def _statics(leaf, names) -> tuple:
    return tuple(getattr(leaf, s) for s in names)


def _probe_params(rep: C.Repeat, leaf0, fields, statics):
    """Stack the per-position leaf parameters to [n, lanes, ...].

    Fast path: call ``codec_fn`` ONCE with ``arange(n)`` - elementwise
    closures (everything in this repo: ``mu[:, d]``-style slicing of a
    [lanes, n, ...] parent) then gather the whole parameter grid in one
    op, which is an exact copy in any compilation context. The result
    is spot-validated against eagerly probed positions {0, n//2, n-1};
    any surprise (shape, type, static fields, values) falls back to
    probing all ``n`` positions one by one - always correct, just O(n)
    dispatches.
    """
    n = rep.n
    vec = None
    try:
        vec = rep.codec_fn(jnp.arange(n, dtype=jnp.int32))
    except Exception:
        vec = None
    if vec is not None and type(vec) is type(leaf0) \
            and _statics(vec, statics) == _statics(leaf0, statics):
        out = []
        for name in fields:
            s0 = jnp.shape(getattr(leaf0, name))
            vv = getattr(vec, name)
            if jnp.shape(vv) != s0[:1] + (n,) + s0[1:]:
                out = None
                break
            out.append(jnp.moveaxis(jnp.asarray(vv), 1, 0))
        if out is not None:
            for d in sorted({0, n // 2, n - 1}):
                lf = rep.codec_fn(d)
                if type(lf) is not type(leaf0) or \
                        _statics(lf, statics) != _statics(leaf0, statics):
                    out = None
                    break
                if not all(bool(jnp.array_equal(arr[d], getattr(lf, nm)))
                           for nm, arr in zip(fields, out)):
                    out = None
                    break
            if out is not None:
                return out
    # Slow path: probe every position (heterogeneity checks included).
    leaves = [rep.codec_fn(d) for d in range(n)]
    if not all(type(lf) is type(leaf0) for lf in leaves):
        return None
    if not _same([_statics(lf, statics) for lf in leaves]):
        return None
    return [jnp.stack([jnp.asarray(getattr(lf, nm)) for lf in leaves])
            for nm in fields]


def _validate_tables(tables: jnp.ndarray, precision: int,
                     what: str) -> None:
    """Frequency-soundness gate on lowered fixed-point tables: exact
    span, monotone starts, no zero-mass symbol. Runs once per lowering
    (the tables are already concrete), so a broken table fails here
    naming the subtree instead of as a hex mismatch at decode time."""
    t = np.asarray(tables).astype(np.int64)
    total = 1 << precision
    if (t[..., 0] != 0).any() or (t[..., -1] != total).any():
        raise ValueError(
            f"codecs.compile: contract violation (freq-sum) in {what}: "
            f"table spans [{int(t[..., 0].min())}, "
            f"{int(t[..., -1].max())}] instead of exactly "
            f"[0, 2^{precision}]")
    d = np.diff(t, axis=-1)
    if (d < 0).any():
        raise ValueError(
            f"codecs.compile: contract violation (starts-monotone) in "
            f"{what}: cumulative starts decrease")
    if (d < 1).any():
        raise ValueError(
            f"codecs.compile: contract violation (freq-zero) in {what}: "
            "a symbol has zero frequency and would decode to a "
            "neighbour silently")


def _validate_grid_params(arr: jnp.ndarray, name: str, what: str,
                          positive: bool = False) -> None:
    a = np.asarray(arr)
    if not np.isfinite(a).all():
        raise ValueError(
            f"codecs.compile: contract violation (starts-monotone) in "
            f"{what}: non-finite {name}")
    if positive and (a <= 0).any():
        raise ValueError(
            f"codecs.compile: contract violation (starts-monotone) in "
            f"{what}: {name} must be strictly positive (a non-positive "
            "scale flips the CDF and breaks the decode bisection)")


def _lower_repeat(rep: C.Repeat, donate: bool) -> Optional[Codec]:
    """Probe a ``Repeat``'s positions; fuse when the leaf family allows.

    Returns ``None`` when the body is unrecognized (heterogeneous,
    closure-opaque, degenerate) - the caller falls back to the
    interpreted ``Repeat``, which is always correct.
    """
    if rep.n <= 0:
        return None
    try:
        leaf0 = rep.codec_fn(0)
    except Exception:
        return None
    family = next(((cls, fields, statics)
                   for cls, fields, statics in _FAMILIES
                   if isinstance(leaf0, cls)), None)
    if family is None:
        return None
    cls, fields, statics = family
    try:
        params = _probe_params(rep, leaf0, fields, statics)
    except Exception:
        params = None
    if params is None:
        return None

    if cls is L.Uniform:
        return _GridRepeat("uniform", None, None, rep.n, leaf0.bits,
                           leaf0.precision, rep.out_dtype, donate)
    if cls is L.DiscretizedGaussian:
        mu, sigma = (p.astype(jnp.float32) for p in params)
        what = f"Repeat[DiscretizedGaussian, n={rep.n}]"
        _validate_grid_params(mu, "mu", what)
        _validate_grid_params(sigma, "sigma", what, positive=True)
        return _GridRepeat("gaussian", mu, sigma, rep.n, leaf0.bits,
                           leaf0.precision, rep.out_dtype, donate)
    if cls is L.DiscretizedLogistic:
        mu, scale = (p.astype(jnp.float32) for p in params)
        what = f"Repeat[DiscretizedLogistic, n={rep.n}]"
        _validate_grid_params(mu, "mu", what)
        _validate_grid_params(scale, "scale", what, positive=True)
        return _GridRepeat("logistic", mu, scale, rep.n, leaf0.bits,
                           leaf0.precision, rep.out_dtype, donate)

    # Table families: the fixed-point tables are built in ONE vectorized
    # evaluation - the same elementwise arithmetic as the per-position
    # leaf (`_freq1`/`_table`) broadcast over the position axis, so the
    # bits are identical (eager elementwise ops are shape-independent).
    if cls is Bernoulli:
        total = 1 << leaf0.precision
        p = jax.nn.sigmoid(params[0].astype(jnp.float32))  # [n, lanes]
        f1 = jnp.round(p * (total - 2)).astype(jnp.uint32) + 1
        tables = jnp.stack(
            [jnp.zeros_like(f1), jnp.uint32(total) - f1,
             jnp.full_like(f1, jnp.uint32(total))], axis=-1)
        _validate_tables(tables, leaf0.precision,
                         f"Repeat[Bernoulli, n={rep.n}]")
        return _TableRepeat(tables, leaf0.precision, rep.out_dtype,
                            donate)
    if cls is BetaBinomial:
        alpha, beta = params
        ks = jnp.arange(leaf0.n + 1, dtype=jnp.float32)
        logp = beta_binomial_log_pmf(
            ks[None, None, :], leaf0.n,
            alpha[..., None].astype(jnp.float32),
            beta[..., None].astype(jnp.float32))
        tables = ans.probs_to_starts(_stable_softmax(logp),
                                     leaf0.precision)
        _validate_tables(tables, leaf0.precision,
                         f"Repeat[BetaBinomial, n={rep.n}]")
        return _TableRepeat(tables, leaf0.precision, rep.out_dtype,
                            donate)
    if cls is Categorical:
        tables = ans.probs_to_starts(
            _stable_softmax(params[0].astype(jnp.float32)),
            leaf0.precision)
        _validate_tables(tables, leaf0.precision,
                         f"Repeat[Categorical, n={rep.n}]")
        return _TableRepeat(tables, leaf0.precision, rep.out_dtype,
                            donate)
    return None


#: type -> (codec, recurse) -> lowered codec. Extension point for
#: combinators defined outside this package (``stream.BlockChain``
#: registers itself at import time).
_LOWERINGS: Dict[Type, Callable[[Any, Callable], Codec]] = {}


def register_lowering(cls: Type,
                      fn: Callable[[Any, Callable], Codec]) -> None:
    """Register a structural lowering for an external combinator class.

    ``fn(codec, recurse)`` must return a bit-exact rewrite of ``codec``
    (typically the same class over ``recurse``-lowered children).
    """
    _LOWERINGS[cls] = fn


def _lower(codec: Codec, donate: bool = True) -> Codec:
    """Structurally rewrite a codec tree into its fused form."""
    rec = lambda c: _lower(c, donate)
    fn = _LOWERINGS.get(type(codec))
    if fn is not None:
        return fn(codec, rec)
    if isinstance(codec, C.Repeat):
        return _lower_repeat(codec, donate) or codec
    if isinstance(codec, C.Shaped):
        return C.Shaped(rec(codec.inner), codec.shape)
    if isinstance(codec, C.Serial):
        return C.Serial([rec(c) for c in codec.codecs])
    if isinstance(codec, C.TreeCodec):
        leaves, treedef = jax.tree_util.tree_flatten(
            codec.tree, is_leaf=lambda c: isinstance(c, Codec))
        return C.TreeCodec(treedef.unflatten([rec(c) for c in leaves]))
    if isinstance(codec, C.Chained):
        inner_l = rec(codec.inner)
        if isinstance(inner_l, (_FusedBBANS, _FusedBitSwap)):
            # Fixed-point inner: the chain body is bit-stable under
            # fusion, so the whole chain scans inside one program.
            return _FusedChained(inner_l, codec.n, donate)
        # scan=False: a lax.scan would trace the float evaluations into
        # one fused program, breaking the canonical-eager contract; the
        # Python chain loop is per-datapoint (cheap), not per-symbol.
        return C.Chained(inner_l, codec.n, scan=False)
    if isinstance(codec, C.BBANS):
        fused = _lower_fused_bbans(codec, donate)
        if fused is not None:
            return fused
        lik, post = codec.likelihood, codec.posterior
        return C.BBANS(prior=rec(codec.prior),
                       likelihood=lambda y: rec(lik(y)),
                       posterior=lambda s: rec(post(s)))
    if isinstance(codec, C.BitSwap):
        fused = _lower_fused_bitswap(codec, donate)
        if fused is not None:
            return fused
        layers = tuple(
            (lambda ctx, _p=p: rec(_p(ctx)),
             lambda z, _l=lk: rec(_l(z)))
            for p, lk in codec.layers)
        return C.BitSwap(prior=rec(codec.prior), layers=layers)
    return codec


# ---------------------------------------------------------------------------
# the compiled program
# ---------------------------------------------------------------------------

def _consult_tuning(codec: Codec) -> None:
    """Walk a lowered tree and warm the kernel tuning cache for its
    fused nodes. Only active under ``REPRO_AUTOTUNE=1`` (measured
    autotuning at lowering time is opt-in; without it, cache hits from
    previous runs still apply via ``dispatch.resolve``)."""
    if not os.environ.get("REPRO_AUTOTUNE"):
        return
    from repro.kernels import tuning

    def walk(c: Any) -> None:
        if isinstance(c, _GridRepeat):
            lanes = c.mu.shape[1] if c.mu is not None \
                and jnp.ndim(c.mu) == 2 else None
            tuning.ensure("push_many", lanes=lanes, steps=c.n,
                          lat_bits=c.bits, precision=c.precision)
            tuning.ensure("pop_many_grid", lanes=lanes, steps=c.n,
                          lat_bits=c.bits, precision=c.precision)
        elif isinstance(c, _TableRepeat):
            lanes, tsize = c.tables.shape[1], c.tables.shape[2] - 1
            tuning.ensure("push_many_table", lanes=lanes,
                          table_size=tsize, steps=c.tables.shape[0],
                          precision=c.precision)
            tuning.ensure("pop_many_dyn", lanes=lanes, table_size=tsize,
                          steps=c.tables.shape[0], precision=c.precision)
        elif isinstance(c, C.Shaped):
            walk(c.inner)
        elif isinstance(c, C.Serial):
            for child in c.codecs:
                walk(child)
        elif isinstance(c, C.TreeCodec):
            for child in jax.tree_util.tree_leaves(
                    c.tree, is_leaf=lambda x: isinstance(x, Codec)):
                walk(child)
        elif isinstance(c, C.Chained):
            walk(c.inner)

    walk(codec)


class CompiledCodec(Codec):
    """A codec lowered into fused kernel-backed execution.

    Drop-in for the source codec anywhere a ``Codec`` is accepted
    (container, stream, engine): same wire bytes, a handful of jitted
    integer coder programs per direction instead of one host dispatch
    per symbol. The ``ANSStack`` flowing through those programs is
    donated by default, so coder state updates in place on backends
    that support donation.

    Note the donation contract: after ``prog.push(stack, x)`` the
    *input* stack's buffers may be invalid - callers must use the
    returned stack (every driver in this repo already does; tests that
    deliberately reuse a stack pass ``donate=False``).
    """

    def __init__(self, codec: Codec, *, donate: bool = True):
        self.source = codec
        self.lowered = _lower(codec, donate)
        _consult_tuning(self.lowered)

    def push(self, stack: ans.ANSStack, x: Any) -> ans.ANSStack:
        return self.lowered.push(stack, x)

    def pop(self, stack: ans.ANSStack):
        return self.lowered.pop(stack)


def compile(codec: Codec, *, donate: bool = True,
            verify: bool = False) -> CompiledCodec:
    """Compile a codec tree into a fused kernel-backed program.

    Returns a ``CompiledCodec`` that codes byte-identically to
    ``codec`` (compiling an already-compiled codec is a no-op).
    Lowered fixed-point tables are always validated for frequency
    soundness (a broken table raises ``ValueError`` here, naming the
    subtree); ``verify=True`` additionally runs the full
    ``repro.analysis`` contract verifier over the source tree and
    raises ``analysis.ContractViolation`` on any error finding.

    Example::

        prog = codecs.compile(codecs.Repeat(
            lambda d: codecs.Uniform(8), 64))
        stack = prog.push(stack, x)        # ONE fused kernel call
    """
    if isinstance(codec, CompiledCodec):
        return codec
    if verify:
        from repro.analysis import check_codec   # lazy: avoid cycle
        check_codec(codec, context="codecs.compile")
    return CompiledCodec(codec, donate=donate)
