"""Fixed-point (integer-quantized) model inference for fused coder programs.

The codec compiler's determinism contract (docs/PERF.md) historically
kept every *model* float evaluation in canonical eager form, because
float32 results in XLA depend on the fusion context: the same network
fused into two different programs can differ by one ulp, flip a
``floor``, and corrupt the stream. That forced an eager-float hop per
``Repeat`` step and capped compiled throughput far below the hardware.

This module removes the restriction the way HiLLoC (arXiv 1912.09953)
does: make the network itself **bit-exact in any compilation context**
by evaluating it in fixed point. The allowed operation set is:

  * int32 add / multiply / matmul / convolution - integer arithmetic is
    associative (mod 2^32), so any XLA fusion, tiling, or reduction
    order produces identical bits;
  * gathers from concrete lookup tables (``sigma_table``,
    ``freq1_table``, ``centre_q_table``) - exact in any context, built
    once on the host exactly like ``discretize.edge_table``;
  * arithmetic right shifts (exact floor division by powers of two) and
    integer clips;
  * int32 -> float32 conversion of values below 2^24 followed by a
    multiply with a power-of-two constant - both single correctly-
    rounded IEEE ops, hence bit-stable.

A quantized network therefore may be traced *inside* the jitted coder
program: ``codecs.compile`` fuses model forward, bucketize, and ANS
renorm into one program per direction (see ``_FusedBBANS`` /
``_FusedBitSwap`` in ``codecs.compile``). The ``FixedPointFn`` marker
is the hand-off: models wrap their quantized posterior / likelihood
builders in it, the interpreter calls it like any other codec factory
(bit-identical eager twin), and the compiler recognizes it and fuses.

Activation/weight layout: values are carried as int32 fixed point with
``QuantConfig.act_bits`` fractional bits (weights use ``w_bits``); a
dense/conv layer accumulates at scale ``act_bits + w_bits`` and shifts
back down. The clip bounds are chosen so a worst-case accumulation over
any layer in this repo stays below 2^31 (no wraparound in practice; and
wraparound would still be deterministic, just wasteful).

Quantized codecs produce *different* wire bytes than their float
parents - they are a different (coarser) model. The parity that
matters, and that ``benchmarks/codec_compile.py`` and the golden/fuzz
suites assert, is quantized-eager == quantized-fused, hex-identical.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ans, discretize
from repro.core.codec import Codec
from repro.codecs import combinators as C
from repro.codecs import leaves as L


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Fixed-point format: fractional bits and integer clip bounds.

    Defaults keep every accumulation in this repo inside int32: with
    ``|act| <= act_clip = 2^11`` (value range +-32) and ``|w| <= w_clip
    = 2^9`` (value range +-8), a 1024-input dense layer or a 3x3x32
    conv accumulates at most ~2^30 before the shift back down.
    """

    act_bits: int = 6        # fractional bits of activations
    w_bits: int = 6          # fractional bits of weights
    act_clip: int = 1 << 11  # |quantized activation| bound
    w_clip: int = 1 << 9     # |quantized weight| bound
    logit_range: float = 16.0   # sigmoid LUT domain (value units)
    logvar_range: float = 10.0  # matches the float models' clip(-10, 10)


# ---------------------------------------------------------------------------
# lookup tables (host-built once, gathered everywhere - exact in any context)
# ---------------------------------------------------------------------------

_SIGMA_TABLES: Dict[Tuple[int, float], jnp.ndarray] = {}
_FREQ1_TABLES: Dict[Tuple[int, int, float], jnp.ndarray] = {}
_CENTRE_Q_TABLES: Dict[Tuple[int, int, int], jnp.ndarray] = {}


def sigma_table(q: QuantConfig) -> jnp.ndarray:
    """``exp(0.5 * lv)`` on the quantized logvar grid, float32[2R+1].

    Index ``i`` corresponds to quantized logvar ``i - R`` (R = range in
    quantized units); entries are strictly positive, so a gathered
    sigma always satisfies the compiler's positivity contract.
    """
    key = (q.act_bits, q.logvar_range)
    if key not in _SIGMA_TABLES:
        with jax.ensure_compile_time_eval():
            r = int(round(q.logvar_range * (1 << q.act_bits)))
            lv = np.arange(-r, r + 1, dtype=np.float64) \
                * (2.0 ** -q.act_bits)
            _SIGMA_TABLES[key] = jnp.asarray(
                np.exp(0.5 * lv).astype(np.float32))
    return _SIGMA_TABLES[key]


def freq1_table(precision: int, q: QuantConfig) -> jnp.ndarray:
    """Bernoulli fixed-point frequency of symbol 1 on the quantized
    logit grid: uint32[2R+1], every entry in [1, 2^precision - 1]."""
    key = (precision, q.act_bits, q.logit_range)
    if key not in _FREQ1_TABLES:
        with jax.ensure_compile_time_eval():
            total = 1 << precision
            r = int(round(q.logit_range * (1 << q.act_bits)))
            logit = np.arange(-r, r + 1, dtype=np.float64) \
                * (2.0 ** -q.act_bits)
            p = np.reciprocal(1.0 + np.exp(-logit))
            f1 = (np.rint(p * (total - 2)) + 1).astype(np.uint32)
            _FREQ1_TABLES[key] = jnp.asarray(f1)
    return _FREQ1_TABLES[key]


def centre_q_table(lat_bits: int, q: QuantConfig) -> jnp.ndarray:
    """``discretize.centre_table`` quantized to int32 Q(act_bits): the
    integer latent values a quantized decoder consumes."""
    key = (lat_bits, q.act_bits, q.act_clip)
    if key not in _CENTRE_Q_TABLES:
        with jax.ensure_compile_time_eval():
            c = np.asarray(discretize.centre_table(lat_bits),
                           dtype=np.float64)
            cq = np.clip(np.rint(c * float(1 << q.act_bits)),
                         -q.act_clip, q.act_clip).astype(np.int32)
            _CENTRE_Q_TABLES[key] = jnp.asarray(cq)
    return _CENTRE_Q_TABLES[key]


# ---------------------------------------------------------------------------
# parameter quantization (host-side, once per model)
# ---------------------------------------------------------------------------

def quantize_weight(w: Any, q: QuantConfig) -> jnp.ndarray:
    """float weights -> int32 Q(w_bits), clipped to +-w_clip."""
    wq = np.clip(np.rint(np.asarray(w, np.float64) * float(1 << q.w_bits)),
                 -q.w_clip, q.w_clip)
    return jnp.asarray(wq.astype(np.int32))


def quantize_bias(b: Any, q: QuantConfig) -> jnp.ndarray:
    """float biases -> int32 at the accumulator scale Q(act+w bits)."""
    scale = float(1 << (q.act_bits + q.w_bits))
    bq = np.clip(np.rint(np.asarray(b, np.float64) * scale),
                 -(1 << 30), 1 << 30)
    return jnp.asarray(bq.astype(np.int32))


def quantize_layer(p: Dict[str, Any], q: QuantConfig) -> Dict[str, Any]:
    """Quantize one ``{"w": ..., "b": ...}`` dense/conv parameter dict."""
    return {"w": quantize_weight(p["w"], q), "b": quantize_bias(p["b"], q)}


def quantize_params(params: Any, q: QuantConfig) -> Any:
    """Quantize a whole parameter pytree of ``{"w", "b"}`` layer dicts
    (nested dicts / lists pass through structurally)."""
    if isinstance(params, dict) and set(params) == {"w", "b"}:
        return quantize_layer(params, q)
    if isinstance(params, dict):
        return {k: quantize_params(v, q) for k, v in params.items()}
    if isinstance(params, (list, tuple)):
        return type(params)(quantize_params(v, q) for v in params)
    raise TypeError(
        f"quantize_params: expected a pytree of dense/conv layer dicts, "
        f"got {type(params).__name__}")


# ---------------------------------------------------------------------------
# fixed-point forward ops (traceable; integer-exact in any context)
# ---------------------------------------------------------------------------

def requantize(acc: jnp.ndarray, q: QuantConfig) -> jnp.ndarray:
    """Accumulator Q(act+w) -> activation Q(act): exact arithmetic
    shift (floor division by 2^w_bits) then clip into the safe range."""
    return jnp.clip(acc >> q.w_bits, -q.act_clip, q.act_clip)


def dense_q(pq: Dict[str, Any], x_q: jnp.ndarray,
            q: QuantConfig) -> jnp.ndarray:
    """int32 Q(act)[lanes, n_in] @ Q(w) weights -> Q(act)[lanes, n_out]."""
    return requantize(x_q @ pq["w"] + pq["b"], q)


def conv_q(pq: Dict[str, Any], x_q: jnp.ndarray, q: QuantConfig,
           stride: int = 1) -> jnp.ndarray:
    """Integer NHWC conv, SAME padding (the quantized ``hvae._conv``)."""
    acc = jax.lax.conv_general_dilated(
        x_q, pq["w"], window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return requantize(acc + pq["b"], q)


def deconv_q(pq: Dict[str, Any], x_q: jnp.ndarray, q: QuantConfig,
             stride: int = 2) -> jnp.ndarray:
    """Integer NHWC transpose conv (the quantized ``hvae._deconv``)."""
    acc = jax.lax.conv_transpose(
        x_q, pq["w"], strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return requantize(acc + pq["b"], q)


def relu_q(x_q: jnp.ndarray) -> jnp.ndarray:
    return jnp.maximum(x_q, 0)


def gaussian_head(mu_q: jnp.ndarray, logvar_q: jnp.ndarray,
                  q: QuantConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Quantized (mu, logvar) heads -> deterministic float32 (mu, sigma).

    ``mu_q`` is below 2^24 so the int->float convert is exact, and the
    power-of-two scale multiply is exact; ``sigma`` is a table gather.
    Both are bit-stable in any fusion context.
    """
    mu = mu_q.astype(jnp.float32) * jnp.float32(2.0 ** -q.act_bits)
    r = int(round(q.logvar_range * (1 << q.act_bits)))
    sigma = jnp.take(sigma_table(q), jnp.clip(logvar_q + r, 0, 2 * r))
    return mu, sigma


def bernoulli_head(logit_q: jnp.ndarray, precision: int,
                   q: QuantConfig) -> jnp.ndarray:
    """Quantized logits -> uint32 fixed-point freq of symbol 1 (LUT)."""
    r = int(round(q.logit_range * (1 << q.act_bits)))
    return jnp.take(freq1_table(precision, q),
                    jnp.clip(logit_q + r, 0, 2 * r))


def latent_centres_q(idx: jnp.ndarray, lat_bits: int,
                     q: QuantConfig) -> jnp.ndarray:
    """Bucket indices -> int32 Q(act) latent values (table gather)."""
    k = 1 << lat_bits
    return jnp.take(centre_q_table(lat_bits, q), jnp.clip(idx, 0, k - 1))


def quantize_input(s: jnp.ndarray, q: QuantConfig) -> jnp.ndarray:
    """Binarized observations {0, 1} -> int32 Q(act), exactly."""
    return s.astype(jnp.int32) << q.act_bits


# ---------------------------------------------------------------------------
# the LUT-Bernoulli leaf (the quantized observation codec)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LutBernoulli(Codec):
    """Bernoulli whose fixed-point frequency comes from a quantized-
    logit lookup table instead of a float ``sigmoid`` evaluation.

    The coding arithmetic is identical to ``codecs.Bernoulli`` given
    the same ``f1``; only the *derivation* of ``f1`` differs (a gather,
    exact in any context, instead of float math). ``f1`` entries must
    lie in ``[1, 2^precision - 1]`` - ``quantize.bernoulli_head``
    guarantees that by table construction.

    Example::

        f1 = bernoulli_head(logit_q, 16, QuantConfig())   # uint32[lanes]
        codec = LutBernoulli(f1[:, 0])
    """

    f1: jnp.ndarray   # uint32[lanes], in [1, 2^precision - 1]
    precision: int = ans.DEFAULT_PRECISION

    def _freqs(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        total = jnp.uint32(1 << self.precision)
        f1 = self.f1.astype(jnp.uint32)
        return total - f1, f1

    def push(self, stack: ans.ANSStack, sym: jnp.ndarray) -> ans.ANSStack:
        f0, f1 = self._freqs()
        is1 = sym.astype(bool)
        start = jnp.where(is1, f0, jnp.uint32(0))
        freq = jnp.where(is1, f1, f0)
        return ans.push(stack, start, freq, self.precision)

    def pop(self, stack: ans.ANSStack) -> Tuple[ans.ANSStack, jnp.ndarray]:
        f0, f1 = self._freqs()
        slot = ans.peek(stack, self.precision)
        is1 = slot >= f0
        start = jnp.where(is1, f0, jnp.uint32(0))
        freq = jnp.where(is1, f1, f0)
        return (ans.pop_update(stack, start, freq, self.precision),
                is1.astype(jnp.int32))


# ---------------------------------------------------------------------------
# the fusion marker
# ---------------------------------------------------------------------------

#: codec families a FixedPointFn may parameterize.
FAMILIES = ("gaussian", "bernoulli")


@dataclasses.dataclass(frozen=True)
class FixedPointFn:
    """A codec-child builder whose parameter computation is fixed-point
    deterministic, i.e. safe to trace into a fused coder program.

    ``fn(ctx)`` computes the family parameters with the operation set
    documented in this module's header:

      * family "gaussian":  ``fn -> (mu, sigma)`` float32[lanes, n],
        coded as ``DiscretizedGaussian`` over the ``bits`` grid;
      * family "bernoulli": ``fn -> f1`` uint32[lanes, n] (fixed-point
        freq of symbol 1), coded as ``LutBernoulli``.

    Calling the instance builds the *interpreted twin* - a standard
    combinator tree over those parameters - so a ``BBANS``/``BitSwap``
    built from ``FixedPointFn`` children runs unchanged (and verifies
    unchanged) on the eager path. ``codecs.compile`` recognizes the
    marker and instead traces ``fn`` inside one jitted program per
    direction, fusing model forward, bucketize, and ANS renorm; wire
    bytes are identical to the eager twin by the fixed-point contract.

    ``shape`` presents the flat [lanes, n] symbol as [lanes, *shape]
    (images); leave empty for flat latent grids.
    """

    fn: Callable[[Any], Any]
    family: str
    n: int
    bits: int = 0                     # grid bits (gaussian family)
    precision: int = ans.DEFAULT_PRECISION
    shape: Tuple[int, ...] = ()

    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(
                f"FixedPointFn: unknown family {self.family!r} "
                f"(expected one of {FAMILIES})")
        if self.family == "gaussian" and self.bits <= 0:
            raise ValueError(
                "FixedPointFn: the gaussian family needs grid bits > 0")

    def params(self, ctx: Any) -> Any:
        """The raw family parameters (what the fused trace consumes)."""
        return self.fn(ctx)

    def __call__(self, ctx: Any) -> Codec:
        """The interpreted twin: a standard combinator tree."""
        if self.family == "gaussian":
            mu, sigma = self.fn(ctx)
            inner: Codec = C.Repeat(
                lambda d: L.DiscretizedGaussian(
                    mu[:, d], sigma[:, d], self.bits, self.precision),
                self.n)
        else:
            f1 = self.fn(ctx)
            inner = C.Repeat(
                lambda d: LutBernoulli(f1[:, d], self.precision), self.n)
        return C.Shaped(inner, self.shape) if self.shape else inner
