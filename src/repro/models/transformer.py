"""Unified transformer backbone for all assigned architectures.

One parameterized block family covers: dense GQA decoders (stablelm,
mistral-nemo, qwen2, smollm), MoE decoders (llama4-scout, arctic), M-RoPE
VLM (qwen2-vl), enc-dec (whisper), hybrid attention+SSM (hymba) and
attention-free RWKV6. Layers are *stacked* ([L, ...] leaves) and applied
with ``lax.scan`` - essential to keep HLO size flat for the 512-device
dry-run compiles.

Entry points:
  init(key, cfg)                          -> params
  forward(params, cfg, tokens|embeds)     -> logits           (train/prefill)
  loss_fn(params, cfg, batch)             -> (loss, metrics)
  init_decode_state(cfg, batch, max_len)  -> state             (KV/SSM)
  decode_step(params, cfg, tok, state, t) -> (logits, state)   (serving)
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention, layers, moe, rwkv6, ssm
from repro.sharding.api import constrain

BIG_WINDOW = 1 << 30


def _compute_dtype(cfg):
    return jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _block_init(key, cfg, *, cross: bool = False, causal: bool = True):
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {"ln1": layers.norm_init(cfg.norm, cfg.d_model),
                         "ln2": layers.norm_init(cfg.norm, cfg.d_model)}
    if cfg.mixer == "rwkv6":
        p["rwkv"] = rwkv6.rwkv_mixer_init(ks[0], cfg)
        p["cmix"] = rwkv6.rwkv_channel_mix_init(ks[1], cfg)
        return p
    p["attn"] = attention.attn_init(ks[0], cfg)
    if cfg.mixer == "hymba":
        p["ssm"] = ssm.ssm_init(ks[1], cfg)
        p["ln_attn_out"] = layers.norm_init(cfg.norm, cfg.d_model)
        p["ln_ssm_out"] = layers.norm_init(cfg.norm, cfg.d_model)
    if cross:
        p["xattn"] = attention.cross_attn_init(ks[2], cfg)
        p["ln_x"] = layers.norm_init(cfg.norm, cfg.d_model)
    if cfg.n_experts:
        p["moe"] = moe.moe_init(ks[3], cfg)
    else:
        p["mlp"] = layers.mlp_init(ks[3], cfg.d_model, cfg.d_ff, cfg.act)
    return p


def _stacked_blocks(key, cfg, n: int, **kw):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: _block_init(k, cfg, **kw))(keys)


def init(key: jax.Array, cfg) -> Dict[str, Any]:
    ks = jax.random.split(key, 6)
    params: Dict[str, Any] = {
        "embed": layers.embed_init(ks[0], cfg.vocab, cfg.d_model),
        "ln_f": layers.norm_init(cfg.norm, cfg.d_model),
        "blocks": _stacked_blocks(ks[1], cfg, cfg.n_layers,
                                  cross=cfg.enc_dec, causal=True),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = layers.embed_init(ks[2], cfg.vocab, cfg.d_model)
    if cfg.enc_dec:
        params["enc_blocks"] = _stacked_blocks(
            ks[3], cfg, cfg.n_enc_layers, cross=False, causal=False)
        params["ln_enc"] = layers.norm_init(cfg.norm, cfg.d_model)
    if cfg.param_dtype != "float32":
        pd = jnp.dtype(cfg.param_dtype)
        params = jax.tree_util.tree_map(lambda p: p.astype(pd), params)
    return params


# ---------------------------------------------------------------------------
# Per-layer static-ish schedules (traced per-layer scalars inside scan)
# ---------------------------------------------------------------------------

def layer_windows(cfg, n_layers: int) -> jnp.ndarray:
    """Sliding-window size per layer (BIG_WINDOW = global attention)."""
    if cfg.sliding_window is None:
        return jnp.full((n_layers,), BIG_WINDOW, jnp.int32)
    idx = jnp.arange(n_layers)
    if cfg.global_attn_every:
        is_global = (idx % cfg.global_attn_every == 0) | \
            (idx == n_layers - 1)
        return jnp.where(is_global, BIG_WINDOW,
                         cfg.sliding_window).astype(jnp.int32)
    return jnp.full((n_layers,), cfg.sliding_window, jnp.int32)


def _dyn_mask(s_q, s_k, window, causal=True):
    qi = jnp.arange(s_q)[:, None]
    ki = jnp.arange(s_k)[None, :]
    m = (ki <= qi) if causal else jnp.ones((s_q, s_k), bool)
    return m & (ki > qi - window)


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _attn_with_window(p, x, cfg, positions, window, enc_out, dt):
    q, k, v = attention._qkv(p["attn"], x, cfg, positions, dt)
    s = x.shape[1]
    if s >= attention.BLOCKWISE_THRESHOLD:
        out = attention.sdpa_blockwise(q, k, v, causal=True, window=window)
    else:
        mask = _dyn_mask(s, s, window, causal=True)
        out = attention.sdpa(q, k, v, mask)
    out = out.reshape(*out.shape[:2], -1)
    return layers.dense(p["attn"]["wo"], out, dt)


def _mixer(p, x, cfg, positions, window, enc_out, dt):
    h = layers.norm_apply(cfg.norm, p["ln1"], x)
    if cfg.mixer == "rwkv6":
        return rwkv6.rwkv_mixer_apply(p["rwkv"], h, cfg, dt)
    if cfg.mixer == "hymba":
        a = _attn_with_window(p, h, cfg, positions, window, enc_out, dt)
        s = ssm.ssm_apply(p["ssm"], h, cfg, dt)
        a = layers.norm_apply(cfg.norm, p["ln_attn_out"], a)
        s = layers.norm_apply(cfg.norm, p["ln_ssm_out"], s)
        return 0.5 * (a + s)
    return _attn_with_window(p, h, cfg, positions, window, enc_out, dt)


def _ffn(p, x, cfg, dt):
    h = layers.norm_apply(cfg.norm, p["ln2"], x)
    if cfg.mixer == "rwkv6":
        return rwkv6.rwkv_channel_mix_apply(p["cmix"], h, dt), 0.0
    if cfg.n_experts:
        out, aux = moe.moe_apply(p["moe"], h, cfg, dt)
        return out, aux
    return layers.mlp_apply(p["mlp"], h, cfg.act, dt), 0.0


def _block_apply(p, x, cfg, positions, window, enc_out, dt):
    x = x + _mixer(p, x, cfg, positions, window, enc_out, dt)
    if enc_out is not None and "xattn" in p:
        h = layers.norm_apply(cfg.norm, p["ln_x"], x)
        x = x + attention.cross_attention(p["xattn"], h, enc_out, cfg, dt)
    f, aux = _ffn(p, x, cfg, dt)
    x = x + f
    x = constrain(x, "batch", "seq", "embed")
    return x, aux


def _remat_wrap(fn, cfg):
    if cfg.remat == "none":
        return fn
    policy = (jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
              if cfg.remat == "dots" else
              jax.checkpoint_policies.nothing_saveable)
    return jax.checkpoint(fn, policy=policy)


def _run_stack(blocks, x, cfg, positions, windows, enc_out, dt):
    block_fn = _remat_wrap(
        functools.partial(_block_apply, cfg=cfg, dt=dt), cfg)

    def body(carry, inp):
        x, aux = carry
        p, w = inp
        x, aux_i = block_fn(p, x, positions=positions, window=w,
                            enc_out=enc_out)
        return (x, aux + aux_i), None

    (x, aux), _ = jax.lax.scan(body, (x, 0.0), (blocks, windows))
    return x, aux


def _positions(cfg, b, s):
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if cfg.rope_kind == "mrope":
        # Text-stream default: t = h = w = position (Qwen2-VL collapses to
        # standard RoPE for pure text; vision patches get true 3D ids).
        pos = jnp.broadcast_to(pos[..., None], (b, s, 3))
    return pos


def encode(params, cfg, enc_embeds):
    """Encoder stack over stub frontend embeddings [B, S_enc, D]."""
    dt = _compute_dtype(cfg)
    b, s, _ = enc_embeds.shape
    x = enc_embeds.astype(dt) + \
        layers.sinusoidal_positions(s, cfg.d_model).astype(dt)[None]
    x = constrain(x, "batch", None, "embed")
    windows = layer_windows(cfg, cfg.n_enc_layers)

    def body(carry, inp):
        p, w = inp
        h = layers.norm_apply(cfg.norm, p["ln1"], carry)
        q, k, v = attention._qkv(p["attn"], h, cfg,
                                 _positions(cfg, b, s), dt)
        if s >= attention.BLOCKWISE_THRESHOLD:
            out = attention.sdpa_blockwise(q, k, v, causal=False)
        else:
            out = attention.sdpa(q, k, v, None)
        carry = carry + layers.dense(
            p["attn"]["wo"], out.reshape(b, s, -1), dt)
        f, _ = _ffn(p, carry, cfg, dt)
        carry = constrain(carry + f, "batch", "seq", "embed")
        return carry, None

    x, _ = jax.lax.scan(body, x, (params["enc_blocks"], windows))
    return layers.norm_apply(cfg.norm, params["ln_enc"], x)


def forward(params, cfg, tokens: Optional[jnp.ndarray] = None, *,
            embeds: Optional[jnp.ndarray] = None,
            enc_out: Optional[jnp.ndarray] = None,
            positions: Optional[jnp.ndarray] = None) -> Tuple[jnp.ndarray,
                                                              jnp.ndarray]:
    """Decoder-side forward -> (logits [B, S, V], moe aux loss)."""
    dt = _compute_dtype(cfg)
    if embeds is None:
        x = layers.embed_apply(params["embed"], tokens, dt)
    else:
        x = embeds.astype(dt)
    b, s = x.shape[:2]
    if cfg.enc_dec:  # absolute positions only for the enc-dec family;
        # RWKV6 is position-free by construction, RoPE archs rotate in-attn.
        x = x + layers.sinusoidal_positions(s, cfg.d_model).astype(dt)[None]
    if positions is None:
        positions = _positions(cfg, b, s)
    windows = layer_windows(cfg, cfg.n_layers)
    x, aux = _run_stack(params["blocks"], x, cfg, positions, windows,
                        enc_out, dt)
    x = layers.norm_apply(cfg.norm, params["ln_f"], x)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = layers.unembed_apply(table, x, dt)
    return logits, aux


# ---------------------------------------------------------------------------
# Loss (chunked over sequence to bound the f32 logits footprint)
# ---------------------------------------------------------------------------

def loss_fn(params, cfg, batch: Dict[str, jnp.ndarray]
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """batch: tokens [B, S] (+ optional 'enc_embeds' / 'embeds').

    Next-token CE in nats/token + MoE aux. The unembed+CE runs in
    ``loss_chunk``-sized sequence chunks under scan.
    """
    dt = _compute_dtype(cfg)
    tokens = batch["tokens"]
    enc_out = None
    if cfg.enc_dec:
        enc_out = encode(params, cfg, batch["enc_embeds"])
    if "embeds" in batch:
        x = batch["embeds"].astype(dt)
    else:
        x = layers.embed_apply(params["embed"], tokens, dt)
    b, s = tokens.shape
    if cfg.enc_dec:
        x = x + layers.sinusoidal_positions(s, cfg.d_model).astype(dt)[None]
    positions = _positions(cfg, b, s)
    windows = layer_windows(cfg, cfg.n_layers)
    x, aux = _run_stack(params["blocks"], x, cfg, positions, windows,
                        enc_out, dt)
    x = layers.norm_apply(cfg.norm, params["ln_f"], x)

    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    targets = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    valid = jnp.concatenate(
        [jnp.ones((b, s - 1), bool), jnp.zeros((b, 1), bool)], axis=1)

    chunk = min(cfg.loss_chunk, s)
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        valid = jnp.pad(valid, ((0, 0), (0, pad)))
    xc = x.reshape(b, n_chunks, chunk, -1).swapaxes(0, 1)
    tc = targets.reshape(b, n_chunks, chunk).swapaxes(0, 1)
    vc = valid.reshape(b, n_chunks, chunk).swapaxes(0, 1)

    @jax.checkpoint  # recompute the V-wide logits in bwd: never keep
    # per-chunk logits alive across the loss scan.
    def ce_chunk_inner(xx, tt, vv):
        logits = layers.unembed_apply(table, xx, dt).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        # One-hot einsum instead of take_along_axis: contracts over the
        # vocab-sharded dim (partial sums + all-reduce) instead of forcing
        # SPMD to gather/replicate the full-vocab logits.
        onehot = jax.nn.one_hot(tt, cfg.vocab, dtype=logits.dtype)
        onehot = constrain(onehot, "batch", None, "vocab")
        tgt = jnp.einsum("bsv,bsv->bs", logits, onehot)
        nll = jnp.where(vv, lse - tgt, 0.0)
        return jnp.sum(nll)

    def ce_chunk(carry, inp):
        xx, tt, vv = inp
        return carry + ce_chunk_inner(xx, tt, vv), None

    total, _ = jax.lax.scan(ce_chunk, jnp.zeros((), jnp.float32),
                            (xc, tc, vc))
    n_valid = jnp.maximum(jnp.sum(valid.astype(jnp.float32)), 1.0)
    ce = total / n_valid
    loss = ce + aux
    return loss, {"ce": ce, "aux": aux,
                  "bits_per_token": ce / jnp.log(2.0)}


# ---------------------------------------------------------------------------
# Prefill (serving): full-prefix pass that also fills per-layer caches
# ---------------------------------------------------------------------------

def prefill(params, cfg, batch: Dict[str, jnp.ndarray], max_len: int
            ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Run the prefix in parallel, returning (last-token logits [B, 1, V],
    decode state with caches filled at cache_len = S)."""
    dt = _compute_dtype(cfg)
    tokens = batch["tokens"]
    b, s = tokens.shape
    enc_out = None
    if cfg.enc_dec:
        enc_out = encode(params, cfg, batch["enc_embeds"])
    if "embeds" in batch:
        x = batch["embeds"].astype(dt)
    else:
        x = layers.embed_apply(params["embed"], tokens, dt)
    if cfg.enc_dec:
        x = x + layers.sinusoidal_positions(s, cfg.d_model).astype(dt)[None]
    positions = _positions(cfg, b, s)
    windows = layer_windows(cfg, cfg.n_layers)

    def body(x, inp):
        p, w = inp
        h = layers.norm_apply(cfg.norm, p["ln1"], x)
        collected = {}
        if cfg.mixer == "rwkv6":
            y, s_final = rwkv6.rwkv_mixer_apply(p["rwkv"], h, cfg, dt,
                                                return_state=True)
            x = x + y
            h2 = layers.norm_apply(cfg.norm, p["ln2"], x)
            x = x + rwkv6.rwkv_channel_mix_apply(p["cmix"], h2, dt)
            collected = {"S": s_final, "prev_x": h[:, -1:],
                         "prev_x_ffn": h2[:, -1:]}
            return x, collected
        q, k, v = attention._qkv(p["attn"], h, cfg, positions, dt)
        if s >= attention.BLOCKWISE_THRESHOLD:
            a = attention.sdpa_blockwise(q, k, v, causal=True, window=w)
        else:
            a = attention.sdpa(q, k, v, _dyn_mask(s, s, w, causal=True))
        a = a.reshape(b, s, -1)
        a = layers.dense(p["attn"]["wo"], a, dt)
        pad_t = max_len - s
        k_pad = jnp.pad(k, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
        v_pad = jnp.pad(v, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
        if cfg.kv_cache_dtype == "int8":
            kq, ks = attention.quantize_kv(k_pad)
            vq, vs = attention.quantize_kv(v_pad)
            collected["k"], collected["v"] = kq, vq
            collected["kv_scales"] = jnp.concatenate([ks, vs], axis=-1)
        else:
            collected["k"], collected["v"] = k_pad, v_pad
        if cfg.mixer == "hymba":
            y_s, h_final = ssm.ssm_apply(p["ssm"], h, cfg, dt,
                                         return_state=True)
            a = 0.5 * (layers.norm_apply(cfg.norm, p["ln_attn_out"], a)
                       + layers.norm_apply(cfg.norm, p["ln_ssm_out"], y_s))
            collected["ssm_h"] = h_final
        x = x + a
        if enc_out is not None and "xattn" in p:
            hx = layers.norm_apply(cfg.norm, p["ln_x"], x)
            x = x + attention.cross_attention(p["xattn"], hx, enc_out,
                                              cfg, dt)
        f, _ = _ffn(p, x, cfg, dt)
        x = x + f
        return x, collected

    x, collected = jax.lax.scan(body, x, (params["blocks"], windows))
    x = layers.norm_apply(cfg.norm, params["ln_f"], x[:, -1:])
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = layers.unembed_apply(table, x, dt)

    state: Dict[str, Any] = dict(collected)
    state["cache_len"] = jnp.asarray(s, jnp.int32)
    if enc_out is not None:
        state["enc_out"] = enc_out
    return logits, state


# ---------------------------------------------------------------------------
# Decode (serving): one token against per-layer KV caches / SSM states
# ---------------------------------------------------------------------------

def init_decode_state(cfg, batch: int, max_len: int,
                      enc_out: Optional[jnp.ndarray] = None,
                      dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Allocate per-layer caches, stacked on a leading [L] axis."""
    l, dh, hkv = cfg.n_layers, cfg.head_dim, cfg.n_kv_heads
    n_heads = max(cfg.n_heads, 1)
    state: Dict[str, Any] = {"cache_len": jnp.zeros((), jnp.int32)}
    if cfg.mixer == "rwkv6":
        h = cfg.d_model // cfg.head_dim
        state["S"] = jnp.zeros((l, batch, h, cfg.head_dim, cfg.head_dim),
                               jnp.float32)
        state["prev_x"] = jnp.zeros((l, batch, 1, cfg.d_model), dtype)
        state["prev_x_ffn"] = jnp.zeros((l, batch, 1, cfg.d_model), dtype)
        return state
    kv_shape = (l, batch, max_len, hkv, dh)
    if cfg.kv_cache_dtype == "int8":
        state["k"] = jnp.zeros(kv_shape, jnp.int8)
        state["v"] = jnp.zeros(kv_shape, jnp.int8)
        state["kv_scales"] = jnp.zeros((l, batch, max_len, hkv, 2),
                                       jnp.float32)
    else:
        state["k"] = jnp.zeros(kv_shape, dtype)
        state["v"] = jnp.zeros(kv_shape, dtype)
    if cfg.mixer == "hymba":
        hh, pp, nn = ssm.ssm_head_dims(cfg)
        state["ssm_h"] = jnp.zeros((l, batch, hh, pp, nn), jnp.float32)
    if cfg.enc_dec and enc_out is not None:
        state["enc_out"] = enc_out
    return state


def decode_step(params, cfg, tok: jnp.ndarray, state: Dict[str, Any]
                ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """tok [B, 1] -> (logits [B, 1, V], new state). cache_len advances."""
    dt = _compute_dtype(cfg)
    x = layers.embed_apply(params["embed"], tok, dt)
    return decode_step_embeds(params, cfg, x, state)


def decode_step_embeds(params, cfg, x: jnp.ndarray, state: Dict[str, Any]
                       ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Like ``decode_step`` but from a provided embedding [B, 1, D]
    (soft-prompt / latent-prefix feeding; used by LatentLM)."""
    dt = _compute_dtype(cfg)
    x = x.astype(dt)
    b = x.shape[0]
    t = state["cache_len"]
    if cfg.enc_dec:
        ang = (t.astype(jnp.float32) /
               (10000.0 ** (jnp.arange(0, cfg.d_model, 2,
                                       dtype=jnp.float32) / cfg.d_model)))
        x = x + jnp.concatenate([jnp.sin(ang), jnp.cos(ang)]
                                ).astype(dt)[None, None, :]
    windows = layer_windows(cfg, cfg.n_layers)
    enc_out = state.get("enc_out")

    if cfg.mixer == "rwkv6":
        def body(x, inp):
            p, s_l, prev, prev_f = inp
            h = layers.norm_apply(cfg.norm, p["ln1"], x)
            y, new = rwkv6.rwkv_decode_step(
                p["rwkv"], h, cfg, {"S": s_l, "prev_x": prev}, dt)
            x = x + y
            h2 = layers.norm_apply(cfg.norm, p["ln2"], x)
            x = x + rwkv6.rwkv_channel_mix_apply(p["cmix"], h2, dt,
                                                 prev_x=prev_f)
            return x, (new["S"], h, h2)

        x, (new_s, new_prev, new_prev_f) = jax.lax.scan(
            body, x, (params["blocks"], state["S"], state["prev_x"],
                      state["prev_x_ffn"]))
        state = dict(state, S=new_s, prev_x=new_prev,
                     prev_x_ffn=new_prev_f, cache_len=t + 1)
    else:
        int8_kv = cfg.kv_cache_dtype == "int8"

        def body(x, inp):
            inp = list(inp)
            p, k_l, v_l, w = inp[:4]
            rest = inp[4:]
            scales_l = rest.pop(0) if int8_kv else None
            hs = rest.pop(0) if cfg.mixer == "hymba" else None
            h = layers.norm_apply(cfg.norm, p["ln1"], x)
            att_out = attention.decode_attention(
                p["attn"], h, cfg, k_l, v_l, t, dt, window=w,
                kv_scales=scales_l)
            if int8_kv:
                a, k_l, v_l, scales_l = att_out
            else:
                a, k_l, v_l = att_out
            if cfg.mixer == "hymba":
                y_s, new_h = ssm.ssm_decode_step(p["ssm"], h, cfg,
                                                 {"h": hs}, dt)
                a = 0.5 * (layers.norm_apply(cfg.norm, p["ln_attn_out"], a)
                           + layers.norm_apply(cfg.norm, p["ln_ssm_out"],
                                               y_s))
            x = x + a
            if enc_out is not None and "xattn" in p:
                hx = layers.norm_apply(cfg.norm, p["ln_x"], x)
                x = x + attention.cross_attention(p["xattn"], hx, enc_out,
                                                  cfg, dt)
            f, _ = _ffn(p, x, cfg, dt)
            x = x + f
            outs = (k_l, v_l)
            if int8_kv:
                outs = outs + (scales_l,)
            if cfg.mixer == "hymba":
                outs = outs + (new_h["h"],)
            return x, outs

        ins = (params["blocks"], state["k"], state["v"], windows)
        if int8_kv:
            ins = ins + (state["kv_scales"],)
        if cfg.mixer == "hymba":
            ins = ins + (state["ssm_h"],)
        x, outs = jax.lax.scan(body, x, ins)
        outs = list(outs)
        state = dict(state, k=outs.pop(0), v=outs.pop(0), cache_len=t + 1)
        if int8_kv:
            state["kv_scales"] = outs.pop(0)
        if cfg.mixer == "hymba":
            state["ssm_h"] = outs.pop(0)

    x = layers.norm_apply(cfg.norm, params["ln_f"], x)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = layers.unembed_apply(table, x, dt)
    return logits, state
