"""Attention: GQA/MQA/MHA with RoPE variants, sliding windows, cross
attention, and single-token cached decode (flash-decoding-style sharded
softmax over the KV sequence).

Layouts:
  q:        [B, S, Hq, Dh]
  k/v:      [B, S, Hkv, Dh]
  KV cache: [B, T, Hkv, Dh] (sequence axis shardable -> 'kv_seq')
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.sharding.api import constrain


class AttnParams(NamedTuple):
    pass  # params are plain dicts; this module is functional


def attn_init(key, cfg):
    d, dh = cfg.d_model, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": layers.dense_init(ks[0], d, (cfg.n_heads, dh), cfg.qkv_bias),
        "wk": layers.dense_init(ks[1], d, (cfg.n_kv_heads, dh),
                                cfg.qkv_bias),
        "wv": layers.dense_init(ks[2], d, (cfg.n_kv_heads, dh),
                                cfg.qkv_bias),
        "wo": layers.dense_init(ks[3], cfg.n_heads * dh, d),
    }


def cross_attn_init(key, cfg):
    return attn_init(key, cfg)


def _split_gqa(q, n_kv):
    """[B, S, Hq, Dh] -> [B, S, Hkv, G, Dh]."""
    b, s, hq, dh = q.shape
    return q.reshape(b, s, n_kv, hq // n_kv, dh)


def _qkv(p, x, cfg, positions, compute_dtype):
    q = layers.dense(p["wq"], x, compute_dtype)
    k = layers.dense(p["wk"], x, compute_dtype)
    v = layers.dense(p["wv"], x, compute_dtype)
    if cfg.rope_kind == "rope":
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope_kind == "mrope":
        q = layers.apply_mrope(q, positions, cfg.mrope_sections,
                               cfg.rope_theta)
        k = layers.apply_mrope(k, positions, cfg.mrope_sections,
                               cfg.rope_theta)
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "kv_heads", None)
    v = constrain(v, "batch", None, "kv_heads", None)
    return q, k, v


def _mask(s_q: int, s_k: int, causal: bool,
          sliding_window: Optional[int], q_offset: int = 0) -> jnp.ndarray:
    qi = jnp.arange(s_q)[:, None] + q_offset
    ki = jnp.arange(s_k)[None, :]
    m = jnp.ones((s_q, s_k), bool)
    if causal:
        m &= ki <= qi
    if sliding_window is not None:
        m &= ki > qi - sliding_window
    return m


def sdpa(q, k, v, mask: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Grouped scaled dot-product attention.

    q [B, Sq, Hq, Dh]; k, v [B, Sk, Hkv, Dh]; mask broadcastable to
    [B, Hkv, G, Sq, Sk] or [Sq, Sk]. Softmax statistics in f32.
    """
    b, sq, hq, dh = q.shape
    hkv = k.shape[2]
    qg = _split_gqa(q, hkv)  # [B, Sq, Hkv, G, Dh]
    scale = dh ** -0.5
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32)
    logits = logits * scale
    if mask is not None:
        logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(b, sq, hq, dh)


# Sequences at or above this length use the blockwise (flash-style)
# online-softmax path: O(chunk^2) score memory instead of O(S^2).
BLOCKWISE_THRESHOLD = 2048
Q_CHUNK = 1024
KV_CHUNK = 1024
_NEG_INF = -1e30


def _block_mask(q_ids, k_ids, sk, causal, window):
    valid = k_ids[None, :] < sk
    if causal:
        valid &= k_ids[None, :] <= q_ids[:, None]
    # window is an f32 scalar (custom_vjp-friendly cotangent type).
    valid &= k_ids[None, :].astype(jnp.float32) > \
        q_ids[:, None].astype(jnp.float32) - window
    return valid


def _flash_fwd_impl(qg, k, v, window, *, causal, q_offset, q_chunk,
                    kv_chunk, sk):
    """qg [B, Sq_pad, Hkv, G, Dh]; k/v [B, Sk_pad, Hkv, Dh] ->
    (out f32 [B, Hkv, G, Sq_pad, Dh], lse f32 [B, Hkv, G, Sq_pad])."""
    b, sq_pad, hkv, g, dh = qg.shape
    nq = sq_pad // q_chunk
    nk = k.shape[1] // kv_chunk
    scale = dh ** -0.5
    kb = k.reshape(b, nk, kv_chunk, hkv, dh).swapaxes(0, 1)
    vb = v.reshape(b, nk, kv_chunk, hkv, dh).swapaxes(0, 1)
    qb = qg.reshape(b, nq, q_chunk, hkv, g, dh).swapaxes(0, 1)

    def q_block(args):
        qi_block, qc = args
        q_ids = qc * q_chunk + jnp.arange(q_chunk) + q_offset

        def kv_step(carry, args2):
            m, l, acc = carry
            kv, vv, kc = args2
            k_ids = kc * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qi_block, kv
                           ).astype(jnp.float32) * scale
            valid = _block_mask(q_ids, k_ids, sk, causal, window)
            s = jnp.where(valid[None, None, None], s, _NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vv.dtype), vv
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, q_chunk), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_chunk, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (kb, vb, jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return out, lse

    outs, lses = jax.lax.map(q_block, (qb, jnp.arange(nq)))
    # [nq, B, Hkv, G, qc, *] -> [B, Hkv, G, Sq_pad, *]
    out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(b, hkv, g, sq_pad, dh)
    lse = lses.transpose(1, 2, 3, 0, 4).reshape(b, hkv, g, sq_pad)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def _flash(qg, k, v, window, causal, q_offset, q_chunk, kv_chunk, sk):
    out, _ = _flash_fwd_impl(qg, k, v, window, causal=causal,
                             q_offset=q_offset, q_chunk=q_chunk,
                             kv_chunk=kv_chunk, sk=sk)
    return out


def _flash_fwd(qg, k, v, window, causal, q_offset, q_chunk, kv_chunk, sk):
    out, lse = _flash_fwd_impl(qg, k, v, window, causal=causal,
                               q_offset=q_offset, q_chunk=q_chunk,
                               kv_chunk=kv_chunk, sk=sk)
    # Flash residuals: only (q, k, v, window, out, lse) - O(S), not O(S^2).
    return out, (qg, k, v, window, out, lse)


def _flash_bwd(causal, q_offset, q_chunk, kv_chunk, sk, res, dout):
    """Blockwise backward: recompute p per (q, kv) block pair; dk/dv are
    single accumulators updated in place across the scan (never saved
    per-step - this is primal computation, not differentiated)."""
    qg, k, v, window, out, lse = res
    b, sq_pad, hkv, g, dh = qg.shape
    nq = sq_pad // q_chunk
    nk = k.shape[1] // kv_chunk
    scale = dh ** -0.5
    dout = dout.astype(jnp.float32)
    # delta[t] = sum_d dout[t, d] * out[t, d]
    delta = jnp.sum(dout * out, axis=-1)  # [B, Hkv, G, Sq_pad]

    qb = qg.reshape(b, nq, q_chunk, hkv, g, dh).swapaxes(0, 1)
    dob = dout.reshape(b, hkv, g, nq, q_chunk, dh).transpose(
        3, 0, 1, 2, 4, 5)
    lseb = lse.reshape(b, hkv, g, nq, q_chunk).transpose(3, 0, 1, 2, 4)
    deltab = delta.reshape(b, hkv, g, nq, q_chunk).transpose(
        3, 0, 1, 2, 4)
    kb = k.reshape(b, nk, kv_chunk, hkv, dh).swapaxes(0, 1)
    vb = v.reshape(b, nk, kv_chunk, hkv, dh).swapaxes(0, 1)

    def q_step(carry, args):
        dk, dv = carry
        qi_block, do, ls, dl, qc = args
        q_ids = qc * q_chunk + jnp.arange(q_chunk) + q_offset

        def kv_step(carry2, args2):
            dq_i, dk, dv = carry2
            kv, vv, kc = args2
            k_ids = kc * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qi_block, kv
                           ).astype(jnp.float32) * scale
            valid = _block_mask(q_ids, k_ids, sk, causal, window)
            s = jnp.where(valid[None, None, None], s, _NEG_INF)
            p = jnp.exp(s - ls[..., None])              # [B,H,G,qc,kc]
            dp = jnp.einsum("bhgqd,bkhd->bhgqk", do,
                            vv.astype(jnp.float32))
            ds = p * (dp - dl[..., None]) * scale
            dv_blk = jnp.einsum("bhgqk,bhgqd->bkhd", p, do)
            dk_blk = jnp.einsum("bhgqk,bqhgd->bkhd", ds,
                                qi_block.astype(jnp.float32))
            dq_i = dq_i + jnp.einsum("bhgqk,bkhd->bqhgd", ds,
                                     kv.astype(jnp.float32))
            dk = jax.lax.dynamic_update_slice_in_dim(
                dk, dk_blk + jax.lax.dynamic_slice_in_dim(
                    dk, kc * kv_chunk, kv_chunk, 1), kc * kv_chunk, 1)
            dv = jax.lax.dynamic_update_slice_in_dim(
                dv, dv_blk + jax.lax.dynamic_slice_in_dim(
                    dv, kc * kv_chunk, kv_chunk, 1), kc * kv_chunk, 1)
            return (dq_i, dk, dv), None

        dq0 = jnp.zeros_like(qi_block, jnp.float32)
        (dq_i, dk, dv), _ = jax.lax.scan(
            kv_step, (dq0, dk, dv), (kb, vb, jnp.arange(nk)))
        return (dk, dv), dq_i

    dk0 = jnp.zeros((b, k.shape[1], hkv, dh), jnp.float32)
    dv0 = jnp.zeros_like(dk0)
    (dk, dv), dqs = jax.lax.scan(
        q_step, (dk0, dv0), (qb, dob, lseb, deltab, jnp.arange(nq)))
    dq = dqs.swapaxes(0, 1).reshape(b, sq_pad, hkv, g, dh)
    return (dq.astype(qg.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            jnp.zeros_like(window))


_flash.defvjp(_flash_fwd, _flash_bwd)


def sdpa_blockwise(q, k, v, *, causal: bool, window=None, q_offset: int = 0,
                   q_chunk: int = Q_CHUNK, kv_chunk: int = KV_CHUNK
                   ) -> jnp.ndarray:
    """Flash-attention SDPA: online softmax forward, block-recomputing
    custom-VJP backward. Residual memory is O(S), score memory O(chunk^2).

    This jnp implementation is the reference for the Pallas flash kernel
    (kernels/flash). ``window`` may be a traced scalar (per-layer sliding
    windows); None means no window.
    """
    b, sq, hq, dh = q.shape
    hkv = k.shape[2]
    q_chunk = min(q_chunk, max(sq, 1))
    kv_chunk = min(kv_chunk, max(k.shape[1], 1))
    nq = -(-sq // q_chunk)
    sq_pad = nq * q_chunk
    sk = k.shape[1]
    nk = -(-sk // kv_chunk)
    sk_pad = nk * kv_chunk

    qg = _split_gqa(q, hkv)
    if sq_pad != sq:
        qg = jnp.pad(qg, ((0, 0), (0, sq_pad - sq), (0, 0), (0, 0),
                          (0, 0)))
    if sk_pad != sk:
        k = jnp.pad(k, ((0, 0), (0, sk_pad - sk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk_pad - sk), (0, 0), (0, 0)))
    if window is None:
        window = jnp.asarray(float(1 << 30), jnp.float32)
    else:
        window = jnp.asarray(window, jnp.float32)
    out = _flash(qg, k, v, window, causal, q_offset, q_chunk, kv_chunk,
                 sk)
    # [B, Hkv, G, Sq_pad, Dh] -> [B, Sq, Hq, Dh]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq_pad, hq, dh)[:, :sq]
    return out.astype(v.dtype)


def self_attention(p, x, cfg, positions, *, causal: bool = True,
                   compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    q, k, v = _qkv(p, x, cfg, positions, compute_dtype)
    mask = _mask(x.shape[1], x.shape[1], causal, cfg.sliding_window)
    out = sdpa(q, k, v, mask)
    out = out.reshape(*out.shape[:2], -1)
    return layers.dense(p["wo"], out, compute_dtype)


def cross_attention(p, x, enc_out, cfg, compute_dtype=jnp.bfloat16):
    q = layers.dense(p["wq"], x, compute_dtype)
    k = layers.dense(p["wk"], enc_out, compute_dtype)
    v = layers.dense(p["wv"], enc_out, compute_dtype)
    q = constrain(q, "batch", None, "heads", None)
    if max(x.shape[1], enc_out.shape[1]) >= BLOCKWISE_THRESHOLD:
        out = sdpa_blockwise(q, k, v, causal=False)
    else:
        out = sdpa(q, k, v, None)
    out = out.reshape(*out.shape[:2], -1)
    return layers.dense(p["wo"], out, compute_dtype)


# ---------------------------------------------------------------------------
# Cached decode (one new token against a KV cache)
# ---------------------------------------------------------------------------

def quantize_kv(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """[..., Dh] -> (int8 values, per-vector f32 scale). The decode-cell
    HBM term is dominated by the KV sweep; int8 halves it (hillclimb 3,
    EXPERIMENTS.md section Perf)."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / 127.0 + 1e-9
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray,
                  dtype=jnp.bfloat16) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)

def prefill_kv(p, x, cfg, positions, compute_dtype=jnp.bfloat16):
    """Return (k, v) for the cache from a full prefix pass."""
    _, k, v = _qkv(p, x, cfg, positions, compute_dtype)
    return k, v


def decode_attention(p, x_t, cfg, k_cache, v_cache, cache_len,
                     compute_dtype=jnp.bfloat16,
                     window=None, kv_scales=None) -> Tuple[jnp.ndarray,
                                                          jnp.ndarray,
                                                          jnp.ndarray]:
    """One-token decode. x_t [B, 1, D]; caches [B, T, Hkv, Dh];
    cache_len int32[] (valid prefix length, == position of the new token).

    The new token's k/v are written *in place* at ``cache_len`` (donation
    makes this a true in-place update at run time), then attention runs over
    the full cache with a validity mask. The softmax over the (possibly
    'kv_seq'-sharded) cache axis lowers to partial max/sum + all-reduce -
    the flash-decoding pattern (DESIGN.md section 5).

    Returns (attn output [B, 1, D], new k_cache, new v_cache).
    """
    b, t = k_cache.shape[0], k_cache.shape[1]
    pos = jnp.full((b, 1), cache_len, jnp.int32)
    if cfg.rope_kind == "mrope":
        pos = jnp.broadcast_to(pos[..., None], (b, 1, 3))
    q, k_t, v_t = _qkv(p, x_t, cfg, pos, compute_dtype)

    int8_kv = k_cache.dtype == jnp.int8
    start = (jnp.zeros((), jnp.int32), cache_len.astype(jnp.int32),
             jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))
    if int8_kv:
        # kv_scales [B, T, Hkv, 2] f32: per-(token, head) scales for k, v.
        kq, ks = quantize_kv(k_t)   # ks [B, 1, Hkv, 1]
        vq, vs = quantize_kv(v_t)
        k_cache = jax.lax.dynamic_update_slice(k_cache, kq, start)
        v_cache = jax.lax.dynamic_update_slice(v_cache, vq, start)
        new_scales = jnp.concatenate([ks, vs], axis=-1)  # [B, 1, Hkv, 2]
        kv_scales = jax.lax.dynamic_update_slice(kv_scales, new_scales,
                                                 start)
        k_use = dequantize_kv(k_cache, kv_scales[..., 0:1], compute_dtype)
        v_use = dequantize_kv(v_cache, kv_scales[..., 1:2], compute_dtype)
    else:
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k_t.astype(k_cache.dtype), start)
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v_t.astype(v_cache.dtype), start)
        k_use, v_use = (k_cache.astype(compute_dtype),
                        v_cache.astype(compute_dtype))

    ki = jnp.arange(t)[None, :]
    valid = ki <= cache_len  # slot cache_len now holds the new token
    if window is not None:
        valid &= ki > cache_len - window
    elif cfg.sliding_window is not None:
        valid &= ki > cache_len - cfg.sliding_window
    mask = valid[:, None, None, None, :]  # -> [B, Hkv, G, 1, T]
    out = sdpa(q, k_use, v_use, mask)
    out = out.reshape(b, 1, -1)
    if int8_kv:
        return (layers.dense(p["wo"], out, compute_dtype), k_cache,
                v_cache, kv_scales)
    return layers.dense(p["wo"], out, compute_dtype), k_cache, v_cache
