"""RWKV6 "Finch" token mixer: linear recurrence with *data-dependent
per-channel decay*, computed in MXU-friendly chunks (TPU adaptation of the
CUDA wkv6 kernel - DESIGN.md section 3).

Per head (key dim N, value dim N):

    S_t = diag(w_t) S_{t-1} + k_t v_t^T          w_t = exp(-exp(d_t))
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)

Chunked evaluation (chunk length L): with a_t = log w_t = -exp(d_t) and
inclusive cumsums A_t = sum_{i<=t} a_i, every exponent that appears is a
*difference A_x - A_y with x >= y*, hence <= 0 - unconditionally stable in
f32 (this is why we materialize the [L, L, N] intra-chunk tensor rather
than the classic unstable factored form; the Pallas kernel tiles it in
VMEM).

Faithfulness note (DESIGN.md section 6): data-dependent decay (the RWKV6
signature) is kept, with a LoRA on the decay; the ddlerp token-shift of the
reference implementation is simplified to static per-projection lerp
(RWKV5-style). Channel mixing uses the squared-ReLU RWKV form.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.sharding.api import constrain

CHUNK = 32
DECAY_LORA = 64


def rwkv_mixer_init(key, cfg):
    d = cfg.d_model
    n_heads = d // cfg.head_dim if cfg.n_heads == 0 else cfg.n_heads
    dh = d // n_heads
    ks = jax.random.split(key, 10)
    scale = 1.0 / d ** 0.5
    return {
        "mu": {name: jnp.full((d,), 0.5, jnp.float32)
               for name in ("r", "k", "v", "g", "d")},
        "wr": layers.dense_init(ks[0], d, (n_heads, dh)),
        "wk": layers.dense_init(ks[1], d, (n_heads, dh)),
        "wv": layers.dense_init(ks[2], d, (n_heads, dh)),
        "wg": layers.dense_init(ks[3], d, (n_heads, dh)),
        "decay_base": jnp.full((n_heads, dh), -1.0, jnp.float32),
        "decay_lora_a": (jax.random.normal(ks[4], (d, DECAY_LORA)) *
                         scale).astype(jnp.float32),
        "decay_lora_b": jnp.zeros((DECAY_LORA, n_heads, dh), jnp.float32),
        "bonus_u": jnp.full((n_heads, dh), 0.5, jnp.float32),
        "ln_out": layers.layernorm_init(d),
        "wo": layers.dense_init(ks[5], d, d),
    }


def _token_shift(x, mu):
    """lerp(prev_token, x, mu) - RWKV's 1-step temporal mix."""
    prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return x + (prev - x) * (1.0 - mu)


def _projections(p, x, compute_dtype):
    xs = {name: _token_shift(x, p["mu"][name]) for name in p["mu"]}
    r = layers.dense(p["wr"], xs["r"], compute_dtype)
    k = layers.dense(p["wk"], xs["k"], compute_dtype)
    v = layers.dense(p["wv"], xs["v"], compute_dtype)
    g = layers.dense(p["wg"], xs["g"], compute_dtype)
    # Data-dependent decay (f32: it goes through exp twice).
    lora = jnp.tanh(xs["d"].astype(jnp.float32) @ p["decay_lora_a"])
    dd = jnp.einsum("bsl,lhd->bshd", lora, p["decay_lora_b"])
    d_t = p["decay_base"] + dd
    log_w = -jnp.exp(jnp.clip(d_t, -8.0, 4.0))  # a_t = log w_t <= 0
    return r, k, v, g, log_w


def _chunk_scan(r, k, v, log_w, u, compute_dtype):
    """Chunked WKV6. r/k/v [B, S, H, N] (S % CHUNK == 0), log_w f32 same
    shape, u [H, N]. Returns y [B, S, H, N]."""
    b, s, h, n = r.shape
    l = min(CHUNK, s)
    nc = s // l

    def reshape_chunks(x):
        return x.reshape(b, nc, l, h, n).transpose(1, 0, 3, 2, 4)

    # -> [nc, B, H, L, N]
    rc, kc, vc = map(reshape_chunks, (r, k, v))
    ac = reshape_chunks(log_w.astype(jnp.float32))
    s0 = jnp.zeros((b, h, n, n), jnp.float32)

    def body(s_prev, inp):
        rcc, kcc, vcc, acc = inp          # [B, H, L, N]
        cum = jnp.cumsum(acc, axis=2)     # inclusive A_t
        cum_prev = cum - acc              # exclusive A_{t-1}
        # Cross-chunk: y_cross[t] = (r_t * exp(A_{t-1}))^T S_prev.
        r_dec = rcc.astype(jnp.float32) * jnp.exp(cum_prev)
        y = jnp.einsum("bhtn,bhnm->bhtm", r_dec, s_prev)
        # Intra-chunk: att[t, i, c] = r_t[c] k_i[c] exp(A_{t-1,c} - A_{i,c})
        # for i < t; diagonal uses the bonus u instead.
        expo = cum_prev[:, :, :, None, :] - cum[:, :, None, :, :]
        tri = jnp.tril(jnp.ones((l, l), bool), k=-1)[None, None, :, :, None]
        w_ti = jnp.where(tri, jnp.exp(jnp.minimum(expo, 0.0)), 0.0)
        att = jnp.einsum("bhtc,bhic,bhtic->bhti",
                         rcc.astype(jnp.float32),
                         kcc.astype(jnp.float32), w_ti)
        y = y + jnp.einsum("bhti,bhin->bhtn", att,
                           vcc.astype(jnp.float32))
        # Diagonal bonus term: (r_t . (u * k_t)) v_t.
        diag = jnp.sum(
            rcc.astype(jnp.float32) * kcc.astype(jnp.float32) *
            u.astype(jnp.float32)[None, :, None, :], axis=-1)
        y = y + diag[..., None] * vcc.astype(jnp.float32)
        # State to chunk end: S' = diag(exp(A_L)) S + sum_i exp(A_L - A_i)
        # k_i v_i^T.
        a_last = cum[:, :, -1:, :]                      # [B, H, 1, N]
        k_dec = kcc.astype(jnp.float32) * jnp.exp(a_last - cum)
        s_new = s_prev * jnp.exp(a_last.squeeze(2))[..., None] + \
            jnp.einsum("bhtn,bhtm->bhnm", k_dec, vcc.astype(jnp.float32))
        return s_new, y

    s_final, ys = jax.lax.scan(body, s0, (rc, kc, vc, ac))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(b, s, h, n)
    return y.astype(compute_dtype), s_final


def rwkv_mixer_apply(p, x, cfg, compute_dtype=jnp.bfloat16,
                     return_state: bool = False):
    """Full-sequence WKV6 mixer. x [B, S, D] -> [B, S, D] (optionally also
    the final recurrent state for prefill->decode handoff)."""
    b, s, d = x.shape
    r, k, v, g, log_w = _projections(p, x, compute_dtype)
    pad = (-s) % CHUNK
    if pad:
        padf = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = padf(r), padf(k), padf(v)
        log_w = padf(log_w)  # pad log_w = 0 -> decay 1, k = 0 -> no update
    y, s_final = _chunk_scan(r, k, v, log_w, p["bonus_u"], compute_dtype)
    if pad:
        y = y[:, :s]
    y = y.reshape(b, s, d)
    y = layers.layernorm(p["ln_out"], y)
    y = y * jax.nn.silu(g.reshape(b, s, d))
    y = constrain(y, "batch", None, "embed")
    out = layers.dense(p["wo"], y, compute_dtype)
    if return_state:
        return out, s_final
    return out


def rwkv_decode_step(p, x_t, cfg, state, compute_dtype=jnp.bfloat16):
    """One-token recurrent step.

    x_t [B, 1, D]; state dict with 'S' [B, H, N, N] f32 and 'prev_x'
    [B, 1, D] (token-shift memory). Returns (y [B, 1, D], new state).
    """
    b, _, d = x_t.shape
    prev = state["prev_x"]
    xs = {name: x_t + (prev - x_t) * (1.0 - p["mu"][name])
          for name in p["mu"]}
    r = layers.dense(p["wr"], xs["r"], compute_dtype)[:, 0]
    k = layers.dense(p["wk"], xs["k"], compute_dtype)[:, 0]
    v = layers.dense(p["wv"], xs["v"], compute_dtype)[:, 0]
    g = layers.dense(p["wg"], xs["g"], compute_dtype)[:, 0]
    lora = jnp.tanh(xs["d"][:, 0].astype(jnp.float32) @ p["decay_lora_a"])
    dd = jnp.einsum("bl,lhd->bhd", lora, p["decay_lora_b"])
    w = jnp.exp(-jnp.exp(jnp.clip(p["decay_base"] + dd, -8.0, 4.0)))
    s_prev = state["S"]
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    u = p["bonus_u"][None]
    y = jnp.einsum("bhn,bhnm->bhm", rf, s_prev) + \
        jnp.sum(rf * u * kf, -1, keepdims=True) * vf
    s_new = s_prev * w[..., None] + kf[..., None] * vf[..., None, :]
    y = y.reshape(b, 1, d).astype(compute_dtype)
    y = layers.layernorm(p["ln_out"], y)
    y = y * jax.nn.silu(g.reshape(b, 1, d))
    return (layers.dense(p["wo"], y, compute_dtype),
            {"S": s_new, "prev_x": x_t})


def rwkv_channel_mix_init(key, cfg):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.full((d,), 0.5, jnp.float32),
        "mu_r": jnp.full((d,), 0.5, jnp.float32),
        "wk": layers.dense_init(ks[0], d, f),
        "wr": layers.dense_init(ks[1], d, d),
        "wv": layers.dense_init(ks[2], f, d),
    }


def rwkv_channel_mix_apply(p, x, compute_dtype=jnp.bfloat16,
                           prev_x: Optional[jnp.ndarray] = None):
    if prev_x is None:
        xk = _token_shift(x, p["mu_k"])
        xr = _token_shift(x, p["mu_r"])
    else:
        xk = x + (prev_x - x) * (1.0 - p["mu_k"])
        xr = x + (prev_x - x) * (1.0 - p["mu_r"])
    k = jnp.square(jax.nn.relu(layers.dense(p["wk"], xk, compute_dtype)))
    k = constrain(k, "batch", None, "ff")
    return jax.nn.sigmoid(layers.dense(p["wr"], xr, compute_dtype)) * \
        layers.dense(p["wv"], k, compute_dtype)
