"""Mixture-of-Experts block: top-k routing, capacity-bounded sort dispatch,
expert-parallel all_to_all (DeepSeek/Switch-style), shared-expert and
dense-parallel (Arctic) variants.

Three execution paths sharing one routing implementation:

  * ``dense``    - every expert computes every token, one-hot combine.
                   O(E) FLOPs: correctness oracle + tiny smoke configs only.
  * ``local``    - capacity-bucketed sort dispatch on one device (EP=1).
  * ``ep``       - shard_map over the 'model' axis: tokens are
                   sequence-split across EP ranks, scatter-packed into
                   [E, C, D] buckets, exchanged with all_to_all, FFN'd by
                   the local experts, exchanged back, combined, and
                   all-gathered back to the full sequence. 2x all_to_all +
                   1x all_gather per layer - the production schedule.

Routing is identical across paths (argsort-based, deterministic), so
``dense`` == ``local`` == ``ep`` exactly whenever no token is dropped;
tests assert this.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers
from repro.sharding import api as shard_api

ROUTER_Z_COEF = 1e-3
LOAD_BALANCE_COEF = 1e-2


def _shard_map(f, mesh, in_specs, out_specs):
    """``shard_map`` across jax versions: new releases expose it as
    ``jax.shard_map`` (replication check flag ``check_vma``), older ones
    under ``jax.experimental.shard_map`` (flag ``check_rep``)."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    from jax.experimental.shard_map import shard_map as sm_old
    return sm_old(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)


def moe_init(key, cfg):
    d, f, e = cfg.d_model, cfg.expert_d_ff, cfg.n_experts
    ks = jax.random.split(key, 6)
    scale = 1.0 / d ** 0.5
    p = {
        "router": {"w": (jax.random.normal(ks[0], (d, e)) *
                         scale).astype(jnp.float32)},
        "wi": (jax.random.normal(ks[1], (e, d, f)) * scale
               ).astype(jnp.float32),
        "wg": (jax.random.normal(ks[2], (e, d, f)) * scale
               ).astype(jnp.float32),
        "wo": (jax.random.normal(ks[3], (e, f, d)) * (1.0 / f ** 0.5)
               ).astype(jnp.float32),
    }
    if cfg.shared_expert:
        p["shared"] = layers.mlp_init(ks[4], d, cfg.expert_d_ff, "silu")
    if cfg.dense_ff_parallel:
        p["dense_mlp"] = layers.mlp_init(ks[5], d, cfg.d_ff, "silu")
    return p


def route(router_p, x, cfg, compute_dtype=jnp.bfloat16):
    """x [..., D] -> (gates [..., K], experts int32 [..., K], aux_loss)."""
    logits = layers.dense(router_p, x, jnp.float32)  # router in f32
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    # Aux losses: Switch-style load balance + router z-loss.
    e = cfg.n_experts
    density = jnp.mean(
        jax.nn.one_hot(experts[..., 0], e, dtype=jnp.float32),
        axis=tuple(range(experts.ndim - 1)))
    density_prob = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))
    lb = jnp.sum(density * density_prob) * e * LOAD_BALANCE_COEF
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * ROUTER_Z_COEF
    return gates.astype(compute_dtype), experts.astype(jnp.int32), lb + z


def _expert_ffn(wi, wg, wo, xs, compute_dtype):
    """xs [E, C, D] through per-expert gated MLP -> [E, C, D]."""
    h = jnp.einsum("ecd,edf->ecf", xs, wi.astype(compute_dtype))
    g = jnp.einsum("ecd,edf->ecf", xs, wg.astype(compute_dtype))
    h = jax.nn.silu(h) * g
    return jnp.einsum("ecf,efd->ecd", h, wo.astype(compute_dtype))


def _dispatch_indices(experts: jnp.ndarray, n_experts: int,
                      capacity: int):
    """Deterministic capacity-bounded slots via stable argsort.

    experts int32[T, K] -> (flat token index [T*K], expert id [T*K],
    slot [T*K], keep mask [T*K]).
    """
    t, k = experts.shape
    eid = experts.reshape(-1)
    order = jnp.argsort(eid, stable=True)           # group by expert
    eid_sorted = eid[order]
    counts = jnp.bincount(eid, length=n_experts)
    starts = jnp.cumsum(counts) - counts            # exclusive prefix
    slot_sorted = jnp.arange(t * k) - starts[eid_sorted]
    keep_sorted = slot_sorted < capacity
    # Un-sort back to assignment order.
    inv = jnp.argsort(order, stable=True)
    slot = slot_sorted[inv]
    keep = keep_sorted[inv]
    tok = jnp.repeat(jnp.arange(t), k)
    return tok, eid, slot.astype(jnp.int32), keep


def _capacity(n_tokens: int, cfg) -> int:
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor /
            max(cfg.n_experts, 1))
    return max(c, cfg.top_k)


def _moe_tokens_local(xf, gates, experts, wi, wg, wo, capacity,
                      cfg, compute_dtype):
    """Single-rank capacity dispatch. xf [T, D] -> [T, D]."""
    t, d = xf.shape
    e = wi.shape[0]
    tok, eid, slot, keep = _dispatch_indices(experts, e, capacity)
    # Pack: buffer [E, C, D].
    safe_e = jnp.where(keep, eid, e)     # OOB row -> dropped
    buf = jnp.zeros((e + 1, capacity, d), compute_dtype)
    buf = buf.at[safe_e, slot].set(xf[tok], mode="drop")
    out_buf = _expert_ffn(wi, wg, wo, buf[:e], compute_dtype)
    # Unpack + gate-weighted combine.
    gathered = out_buf[jnp.where(keep, eid, 0), slot]  # [T*K, D]
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    gflat = gates.reshape(-1)[:, None].astype(compute_dtype)
    out = jnp.zeros((t, d), compute_dtype).at[tok].add(gathered * gflat)
    return out


def moe_apply_dense(p, x, cfg, compute_dtype=jnp.bfloat16):
    """O(E) oracle: all experts on all tokens, one-hot combine."""
    gates, experts, aux = route(p["router"], x, cfg, compute_dtype)
    xf = x.reshape(-1, x.shape[-1]).astype(compute_dtype)
    h = jnp.einsum("td,edf->tef", xf, p["wi"].astype(compute_dtype))
    g = jnp.einsum("td,edf->tef", xf, p["wg"].astype(compute_dtype))
    h = jax.nn.silu(h) * g
    yall = jnp.einsum("tef,efd->ted", h, p["wo"].astype(compute_dtype))
    onehot = jax.nn.one_hot(experts.reshape(xf.shape[0], -1),
                            cfg.n_experts, dtype=compute_dtype)
    combine = jnp.einsum("tk,tke->te", gates.reshape(xf.shape[0], -1),
                         onehot)
    out = jnp.einsum("te,ted->td", combine, yall)
    return _finish(p, x, out.reshape(x.shape), cfg, compute_dtype), aux


def _finish(p, x, moe_out, cfg, compute_dtype):
    if cfg.shared_expert:
        moe_out = moe_out + layers.mlp_apply(p["shared"], x, "silu",
                                             compute_dtype)
    if cfg.dense_ff_parallel:
        moe_out = moe_out + layers.mlp_apply(p["dense_mlp"], x, "silu",
                                             compute_dtype)
    return moe_out


def moe_apply(p, x, cfg, compute_dtype=jnp.bfloat16):
    """Production path: EP all_to_all when a mesh with a >1 'model' axis is
    active, local capacity dispatch otherwise. x [B, S, D]."""
    mesh = shard_api.current_mesh()
    ep = mesh.shape.get("model", 1) if mesh is not None else 1
    if ep > 1:
        return _moe_apply_ep(p, x, cfg, mesh, compute_dtype)
    gates, experts, aux = route(p["router"], x, cfg, compute_dtype)
    xf = x.reshape(-1, x.shape[-1]).astype(compute_dtype)
    cap = _capacity(xf.shape[0], cfg)
    out = _moe_tokens_local(xf, gates.reshape(xf.shape[0], -1),
                            experts.reshape(xf.shape[0], -1),
                            p["wi"], p["wg"], p["wo"], cap, cfg,
                            compute_dtype)
    return _finish(p, x, out.reshape(x.shape), cfg, compute_dtype), aux


def _moe_apply_ep(p, x, cfg, mesh, compute_dtype):
    """shard_map EP: flattened tokens are split across the 'model' axis
    (works for train, prefill AND single-token decode), packed into
    capacity buckets, exchanged with all_to_all, FFN'd by local experts,
    exchanged back, combined, and all-gathered. Requires E % ep == 0."""
    b, s, d = x.shape
    ep = mesh.shape["model"]
    e = cfg.n_experts
    assert e % ep == 0, (e, ep)
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]
    n_tok_loc = (b // dp_size) * s       # tokens per data shard
    t_pad = -(-n_tok_loc // ep) * ep     # padded to a multiple of ep
    t_loc = t_pad // ep
    cap = _capacity(t_loc, cfg)

    def inner(xb, router_w, wi, wg, wo):
        # xb [B_loc, S, D] replicated over model; take this rank's tokens.
        idx = jax.lax.axis_index("model")
        xflat = xb.reshape(-1, d)
        if t_pad != n_tok_loc:
            xflat = jnp.pad(xflat, ((0, t_pad - n_tok_loc), (0, 0)))
        xf = jax.lax.dynamic_slice_in_dim(xflat, idx * t_loc, t_loc, 0)
        xf = xf.astype(compute_dtype)      # [T_loc, D]
        gates, experts, aux = route({"w": router_w}, xf, cfg, compute_dtype)
        tok, eid, slot, keep = _dispatch_indices(experts, e, cap)
        safe_e = jnp.where(keep, eid, e)
        buf = jnp.zeros((e + 1, cap, d), compute_dtype)
        buf = buf.at[safe_e, slot].set(xf[tok], mode="drop")[:e]
        # Exchange: [E, C, D] -> [ep, E_loc, C, D] -> a2a -> [ep(src), ...]
        e_loc = e // ep
        buf = buf.reshape(ep, e_loc, cap, d)
        buf = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=0,
                                 tiled=False)
        # [ep_src, E_loc, C, D]: all ranks' tokens for my experts.
        buf = buf.transpose(1, 0, 2, 3).reshape(e_loc, ep * cap, d)
        out = _expert_ffn(wi, wg, wo, buf, compute_dtype)
        # Inverse exchange.
        out = out.reshape(e_loc, ep, cap, d).transpose(1, 0, 2, 3)
        out = jax.lax.all_to_all(out, "model", split_axis=0, concat_axis=0,
                                 tiled=False)
        out = out.reshape(e, cap, d)
        gathered = out[jnp.where(keep, eid, 0), slot]
        gathered = jnp.where(keep[:, None], gathered, 0.0)
        gflat = gates.reshape(-1)[:, None].astype(compute_dtype)
        yc = jnp.zeros_like(xf).at[tok].add(gathered * gflat)
        # Reassemble all token chunks across EP ranks.
        y = jax.lax.all_gather(yc, "model", axis=0, tiled=True)  # [T_pad,D]
        y = y[:n_tok_loc].reshape(xb.shape)
        return y, jax.lax.pmean(aux, "model")

    wi_spec = P("model", None, None)
    out = _shard_map(
        inner, mesh=mesh,
        in_specs=(P(dp_axes, None, None), P(None, None),
                  wi_spec, wi_spec, wi_spec),
        out_specs=(P(dp_axes, None, None), P()),
    )(x, p["router"]["w"], p["wi"], p["wg"], p["wo"])
    y, aux = out
    return _finish(p, x, y, cfg, compute_dtype), aux
