"""Shared neural building blocks: norms, dense, embeddings, RoPE/M-RoPE,
gated MLPs. Pure functions over param pytrees; bf16 compute / f32 params by
default; activations constrained via the logical-axis sharding API.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding.api import constrain


def truncated_normal_init(key, shape, scale, dtype=jnp.float32):
    stddev = scale / max(1.0, (shape[-2] if len(shape) > 1 else 1)) ** 0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) *
            stddev).astype(dtype)


def dense_init(key, d_in: int, d_out: Tuple[int, ...] | int,
               bias: bool = False, dtype=jnp.float32):
    if isinstance(d_out, int):
        d_out = (d_out,)
    w = truncated_normal_init(key, (d_in,) + d_out, 1.0, dtype)
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros(d_out, dtype)
    return p


def dense(p, x, compute_dtype=jnp.bfloat16):
    """x [..., d_in] @ w [d_in, *d_out] -> [..., *d_out]."""
    w = p["w"].astype(compute_dtype)
    y = jnp.tensordot(x.astype(compute_dtype), w, axes=1)
    if "b" in p:
        y = y + p["b"].astype(compute_dtype)
    return y


def rmsnorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p, x, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return y.astype(dt)


def layernorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(p, x, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return y.astype(dt)


def norm_init(kind: str, d: int):
    return rmsnorm_init(d) if kind == "rmsnorm" else layernorm_init(d)


def norm_apply(kind: str, p, x):
    return rmsnorm(p, x) if kind == "rmsnorm" else layernorm(p, x)


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + multimodal M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10000.0) -> jnp.ndarray:
    """x [B, S, H, Dh], positions [B, S] (int) -> same shape."""
    freqs = rope_freqs(x.shape[-1], theta)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, Dh/2]
    sin, cos = jnp.sin(ang)[:, :, None], jnp.cos(ang)[:, :, None]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions: jnp.ndarray,
                sections: Tuple[int, ...],
                theta: float = 10000.0) -> jnp.ndarray:
    """Multimodal RoPE (Qwen2-VL): positions [B, S, 3] (t, h, w ids); the
    frequency bands are partitioned across the 3 position streams.

    ``sections`` are per-stream band counts in *pairs* (sum = Dh/2).
    """
    dh = x.shape[-1]
    assert sum(sections) == dh // 2, (sections, dh)
    freqs = rope_freqs(dh, theta)  # [Dh/2]
    parts = []
    off = 0
    for i, sec in enumerate(sections):
        ang = positions[..., i:i + 1].astype(jnp.float32) * \
            freqs[off:off + sec]
        parts.append(ang)
        off += sec
    ang = jnp.concatenate(parts, -1)  # [B, S, Dh/2]
    sin, cos = jnp.sin(ang)[:, :, None], jnp.cos(ang)[:, :, None]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None]
    ang = pos / (10000.0 ** (dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, act: str):
    ks = jax.random.split(key, 3)
    if act == "silu":  # gated (SwiGLU-style): wi, wg, wo
        return {"wi": dense_init(ks[0], d_model, d_ff),
                "wg": dense_init(ks[1], d_model, d_ff),
                "wo": dense_init(ks[2], d_ff, d_model)}
    return {"wi": dense_init(ks[0], d_model, d_ff, bias=True),
            "wo": dense_init(ks[2], d_ff, d_model, bias=True)}


def mlp_apply(p, x, act: str, compute_dtype=jnp.bfloat16):
    h = dense(p["wi"], x, compute_dtype)
    if act == "silu":
        h = jax.nn.silu(h) * dense(p["wg"], x, compute_dtype)
    else:
        h = jax.nn.gelu(h)
    h = constrain(h, "batch", None, "ff")  # Megatron-SP: ff-sharded, seq gathered
    return dense(p["wo"], h, compute_dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_init(key, vocab: int, d_model: int):
    return {"table": (jax.random.normal(key, (vocab, d_model)) *
                      0.02).astype(jnp.float32)}


def embed_apply(p, ids: jnp.ndarray, compute_dtype=jnp.bfloat16):
    out = jnp.take(p["table"].astype(compute_dtype), ids, axis=0)
    return constrain(out, "batch", "seq", "embed")


def unembed_apply(p, x, compute_dtype=jnp.bfloat16):
    logits = jnp.einsum("...d,vd->...v", x.astype(compute_dtype),
                        p["table"].astype(compute_dtype))
    return constrain(logits, "batch", None, "vocab")
