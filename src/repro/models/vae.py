"""The paper's VAE (section 3.1-3.2) and its BB-ANS codec hooks.

Fully-connected VAE with ReLU activations, diagonal-Gaussian posterior and
standard-normal prior. Two likelihood heads, as in the paper:

  * ``bernoulli``     - binarized MNIST: 1 logit/pixel, hidden 100, latent 40.
  * ``beta_binomial`` - full MNIST (0..255): 2 params/pixel, hidden 200,
    latent 50.

Pure-functional: ``init``/``encode``/``decode``/``elbo`` plus
``make_bb_codec``, which returns the model as a composable
``codecs.BBANS`` combinator (lane = batch element) for use with
``codecs.compress``/``decompress`` or the ``repro.stream`` BBX2 path;
``compiled=True`` lowers it into one fused jit program
(``codecs.compile``) with identical wire bytes.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro import codecs
from repro.codecs import quantize
from repro.core import ans, discretize
from repro.core.distributions import Bernoulli, BetaBinomial

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class VAEConfig:
    input_dim: int = 784
    hidden: int = 100
    latent: int = 40
    likelihood: str = "bernoulli"  # or "beta_binomial"
    # Coding parameters (paper section 2.5.1: 16 bits/latent dim suffice;
    # 10-bit buckets within 16-bit coder precision keep the fixed-point
    # prior-smearing term eps = 2^(lat_bits-precision) below 2%).
    lat_bits: int = 10
    precision: int = 16
    obs_precision: int = 16

    @property
    def obs_symbols(self) -> int:
        return 2 if self.likelihood == "bernoulli" else 256


def paper_config(likelihood: str) -> VAEConfig:
    """The exact two configurations used in the paper's experiments."""
    if likelihood == "bernoulli":
        return VAEConfig(hidden=100, latent=40, likelihood="bernoulli")
    elif likelihood == "beta_binomial":
        return VAEConfig(hidden=200, latent=50, likelihood="beta_binomial")
    raise ValueError(likelihood)


def _dense_init(key, n_in, n_out):
    k1, _ = jax.random.split(key)
    w = jax.random.normal(k1, (n_in, n_out)) * jnp.sqrt(2.0 / n_in)
    return {"w": w.astype(jnp.float32),
            "b": jnp.zeros((n_out,), jnp.float32)}


def init(key: jax.Array, cfg: VAEConfig) -> Params:
    keys = jax.random.split(key, 5)
    out_mult = 1 if cfg.likelihood == "bernoulli" else 2
    return {
        "enc_h": _dense_init(keys[0], cfg.input_dim, cfg.hidden),
        "enc_mu": _dense_init(keys[1], cfg.hidden, cfg.latent),
        "enc_logvar": _dense_init(keys[2], cfg.hidden, cfg.latent),
        "dec_h": _dense_init(keys[3], cfg.latent, cfg.hidden),
        "dec_out": _dense_init(keys[4], cfg.hidden,
                               cfg.input_dim * out_mult),
    }


def _dense(p, x):
    return x @ p["w"] + p["b"]


def _norm_input(cfg: VAEConfig, s: jnp.ndarray) -> jnp.ndarray:
    scale = 1.0 if cfg.likelihood == "bernoulli" else 255.0
    return s.astype(jnp.float32) / scale


def encode(params: Params, cfg: VAEConfig,
           s: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """s int[lanes, input_dim] -> (mu, sigma) each float[lanes, latent]."""
    h = jax.nn.relu(_dense(params["enc_h"], _norm_input(cfg, s)))
    mu = _dense(params["enc_mu"], h)
    logvar = jnp.clip(_dense(params["enc_logvar"], h), -10.0, 10.0)
    return mu, jnp.exp(0.5 * logvar)


def decode(params: Params, cfg: VAEConfig, y: jnp.ndarray) -> jnp.ndarray:
    """y float[lanes, latent] -> obs params.

    bernoulli: logits float[lanes, input_dim];
    beta_binomial: (alpha, beta) float[lanes, input_dim, 2], positive.
    """
    h = jax.nn.relu(_dense(params["dec_h"], y))
    out = _dense(params["dec_out"], h)
    if cfg.likelihood == "bernoulli":
        return out
    ab = out.reshape(out.shape[0], cfg.input_dim, 2)
    return jax.nn.softplus(ab) + 1e-4


def obs_log_prob(cfg: VAEConfig, obs_params: jnp.ndarray,
                 s: jnp.ndarray) -> jnp.ndarray:
    """Sum log p(s|y) over pixels -> float[lanes]."""
    if cfg.likelihood == "bernoulli":
        dist = Bernoulli(obs_params.reshape(-1))
        lp = dist.log_prob(s.reshape(-1).astype(jnp.float32))
        return lp.reshape(s.shape).sum(-1)
    alpha, beta = obs_params[..., 0], obs_params[..., 1]
    from repro.core.distributions import beta_binomial_log_pmf
    lp = beta_binomial_log_pmf(s.astype(jnp.float32), 255, alpha, beta)
    return lp.sum(-1)


def elbo(params: Params, cfg: VAEConfig, key: jax.Array,
         s: jnp.ndarray) -> jnp.ndarray:
    """Per-example ELBO in nats, float[lanes]. -ELBO == expected BB-ANS
    message length (paper eq. 1-2)."""
    mu, sigma = encode(params, cfg, s)
    eps = jax.random.normal(key, mu.shape)
    y = mu + sigma * eps
    obs = decode(params, cfg, y)
    recon = obs_log_prob(cfg, obs, s)
    kl = 0.5 * jnp.sum(mu ** 2 + sigma ** 2 - 1.0
                       - 2.0 * jnp.log(sigma), axis=-1)
    return recon - kl


def elbo_bits_per_dim(params: Params, cfg: VAEConfig, key: jax.Array,
                      s: jnp.ndarray) -> jnp.ndarray:
    return -jnp.mean(elbo(params, cfg, key, s)) / (
        cfg.input_dim * jnp.log(2.0))


def loss(params: Params, cfg: VAEConfig, key: jax.Array,
         s: jnp.ndarray) -> jnp.ndarray:
    return -jnp.mean(elbo(params, cfg, key, s))


# ---------------------------------------------------------------------------
# BB-ANS codec (paper Table 1, App. C) via the composable codecs API
# ---------------------------------------------------------------------------

def quantize_model(params: Params, cfg: VAEConfig,
                   qcfg: quantize.QuantConfig = quantize.QuantConfig()
                   ) -> Params:
    """Quantize the VAE's dense layers to the fixed-point format
    (``codecs.quantize``): int32 weights/biases, ready for the
    integer-exact forward passes below."""
    del cfg
    return quantize.quantize_params(params, qcfg)


def encode_q(qparams: Params, cfg: VAEConfig, qcfg: quantize.QuantConfig,
             s: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fixed-point twin of ``encode``: s int[lanes, input_dim] ->
    deterministic float32 (mu, sigma). Integer matmuls, LUT sigma."""
    x_q = quantize.quantize_input(s, qcfg)
    h = quantize.relu_q(quantize.dense_q(qparams["enc_h"], x_q, qcfg))
    mu_q = quantize.dense_q(qparams["enc_mu"], h, qcfg)
    lv_q = quantize.dense_q(qparams["enc_logvar"], h, qcfg)
    return quantize.gaussian_head(mu_q, lv_q, qcfg)


def decode_freq1_q(qparams: Params, cfg: VAEConfig,
                   qcfg: quantize.QuantConfig,
                   idx: jnp.ndarray) -> jnp.ndarray:
    """Fixed-point twin of ``decode`` (bernoulli): bucket indices
    int[lanes, latent] -> uint32[lanes, input_dim] fixed-point freq of
    pixel = 1 (LUT on the quantized logits)."""
    y_q = quantize.latent_centres_q(idx, cfg.lat_bits, qcfg)
    h = quantize.relu_q(quantize.dense_q(qparams["dec_h"], y_q, qcfg))
    logit_q = quantize.dense_q(qparams["dec_out"], h, qcfg)
    return quantize.bernoulli_head(logit_q, cfg.obs_precision, qcfg)


def make_bb_codec_q(params: Params, cfg: VAEConfig, *,
                    qcfg: quantize.QuantConfig = quantize.QuantConfig(),
                    compiled: bool = False) -> codecs.Codec:
    """The *quantized* VAE as a BBANS combinator (HiLLoC-style).

    Model inference runs in fixed point (``codecs.quantize``), so the
    posterior/likelihood children are ``FixedPointFn`` markers:
    interpreted, the codec behaves like any other combinator tree;
    ``compiled=True`` fuses the whole per-datapoint schedule - network
    forward, bucketize, ANS renorm - into ONE jit program per
    direction (and a ``Chained`` wrapper into one ``lax.scan``
    program for the whole chain). Wire bytes are identical between the
    two paths; they differ from the float model's bytes (a quantized
    net is a coarser model - rate cost is the quantization error).

    Only the bernoulli likelihood is supported in fixed point (the
    beta-binomial table build needs float special functions that have
    no LUT form over a 2-parameter context).
    """
    if cfg.likelihood != "bernoulli":
        raise ValueError(
            "make_bb_codec_q: fixed-point inference supports the "
            f"bernoulli likelihood only (got {cfg.likelihood!r})")
    qp = quantize_model(params, cfg, qcfg)

    posterior = quantize.FixedPointFn(
        lambda s: encode_q(qp, cfg, qcfg, s),
        "gaussian", cfg.latent, cfg.lat_bits, cfg.precision)
    likelihood = quantize.FixedPointFn(
        lambda idx: decode_freq1_q(qp, cfg, qcfg, idx),
        "bernoulli", cfg.input_dim, 0, cfg.obs_precision)
    prior = codecs.Repeat(
        lambda d: codecs.Uniform(cfg.lat_bits, cfg.precision), cfg.latent)
    bb = codecs.BBANS(prior=prior, likelihood=likelihood,
                      posterior=posterior)
    return codecs.compile(bb) if compiled else bb


def make_bb_codec(params: Params, cfg: VAEConfig, *,
                  compiled: bool = False) -> codecs.Codec:
    """The VAE as a composable ``codecs.BBANS`` combinator.

    The latent symbol ``y`` is carried as *bucket indices* int32[lanes,
    latent] under the max-entropy discretization of the prior; the network
    consumes bucket centres. Pixels are coded conditionally-independently
    given y, so intra-datapoint order is free; ``Repeat`` pushes in
    reverse so pops stream in natural order.

    ``compiled=True`` runs the codec through ``codecs.compile``: the
    whole per-datapoint encode/decode (posterior pop, likelihood push,
    prior push, networks included) becomes one fused jit program with
    kernel-backed multi-symbol coding - byte-identical wire, several
    times faster (benchmarks/codec_compile.py). For chained data,
    compiling the whole chain is better still:
    ``codecs.compile(codecs.Chained(make_bb_codec(p, cfg), n))``.

    Use directly with the container:
        blob = codecs.compress(codecs.Chained(make_bb_codec(p, cfg), n),
                               data, lanes=lanes, seed=0)
    """
    def posterior(s):
        mu, sigma = encode(params, cfg, s)
        return codecs.Repeat(
            lambda d: codecs.DiscretizedGaussian(
                mu[:, d], sigma[:, d], cfg.lat_bits, cfg.precision),
            cfg.latent)

    def likelihood(idx):
        y = discretize.bucket_centre(idx, cfg.lat_bits)
        obs_params = decode(params, cfg, y)
        if cfg.likelihood == "bernoulli":
            return codecs.Repeat(
                lambda d: Bernoulli(obs_params[:, d], cfg.obs_precision),
                cfg.input_dim)
        return codecs.Repeat(
            lambda d: BetaBinomial(obs_params[:, d, 0], obs_params[:, d, 1],
                                   255, cfg.obs_precision),
            cfg.input_dim)

    prior = codecs.Repeat(
        lambda d: codecs.Uniform(cfg.lat_bits, cfg.precision), cfg.latent)
    bb = codecs.BBANS(prior=prior, likelihood=likelihood,
                      posterior=posterior)
    return codecs.compile(bb) if compiled else bb
