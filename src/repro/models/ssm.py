"""Mamba2/SSD-style selective SSM head (used by hymba's parallel heads).

Per head (head dim P, state dim N, scalar decay per head - the SSD
structure that makes the chunked "dual" form a plain matmul):

    dt_t   = softplus(x W_dt + b)                  [B, S, H]
    decay  = exp(-dt_t * exp(A_log_h))             scalar per (t, head)
    h_t    = decay_t h_{t-1} + dt_t (u_t  B_t^T)   h [B, H, P, N]
    y_t    = h_t C_t + D u_t                       [B, S, H, P]

Chunked evaluation: within a chunk the scalar-decay recurrence collapses to
a masked [L, L] attention-like matrix (exact SSD duality), computed with
two einsums on the MXU; the carried state crosses chunks in a lax.scan.
All exponents are differences of monotone cumsums -> <= 0, stable in f32.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.sharding.api import constrain

CHUNK = 64


def ssm_head_dims(cfg):
    n_heads = max(cfg.n_heads, 1)
    p = cfg.d_model // n_heads
    return n_heads, p, cfg.ssm_state


def ssm_init(key, cfg):
    h, p, n = ssm_head_dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    return {
        "w_in": layers.dense_init(ks[0], d, (h, p)),
        "w_z": layers.dense_init(ks[1], d, (h, p)),
        "w_B": layers.dense_init(ks[2], d, n),
        "w_C": layers.dense_init(ks[3], d, n),
        "w_dt": layers.dense_init(ks[4], d, h, bias=True),
        "A_log": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h, p), jnp.float32),
        "w_out": layers.dense_init(ks[5], d, d),
    }


def _inputs(p, x, compute_dtype):
    u = layers.dense(p["w_in"], x, compute_dtype)      # [B, S, H, P]
    z = layers.dense(p["w_z"], x, compute_dtype)       # gate
    bmat = layers.dense(p["w_B"], x, compute_dtype)    # [B, S, N]
    cmat = layers.dense(p["w_C"], x, compute_dtype)
    dt = jax.nn.softplus(
        layers.dense(p["w_dt"], x, jnp.float32))       # [B, S, H]
    log_decay = -dt * jnp.exp(p["A_log"])              # <= 0
    return u, z, bmat, cmat, dt, log_decay


def _chunk_scan(u, bmat, cmat, dt, log_decay):
    """SSD chunked scan. u [B,S,H,P]; b/c [B,S,N]; dt/log_decay [B,S,H].
    Returns y [B,S,H,P] (f32)."""
    b, s, h, p = u.shape
    n = bmat.shape[-1]
    l = min(CHUNK, s)
    nc = s // l

    uc = u.astype(jnp.float32).reshape(b, nc, l, h, p)
    bc = bmat.astype(jnp.float32).reshape(b, nc, l, n)
    cc = cmat.astype(jnp.float32).reshape(b, nc, l, n)
    dtc = dt.reshape(b, nc, l, h)
    ac = log_decay.reshape(b, nc, l, h)
    # scan over chunks: move chunk axis first.
    swap = lambda t: jnp.moveaxis(t, 1, 0)
    uc, bc, cc, dtc, ac = map(swap, (uc, bc, cc, dtc, ac))

    h0 = jnp.zeros((b, h, p, n), jnp.float32)

    def body(h_prev, inp):
        u_, b_, c_, dt_, a_ = inp         # [B, L, ...]
        cum = jnp.cumsum(a_, axis=1)      # inclusive [B, L, H]
        # Cross-chunk: y_t = exp(A_t) C_t . h_prev (state before chunk).
        y_cross = jnp.einsum("bln,bhpn->blhp", c_, h_prev) * \
            jnp.exp(cum)[..., None]
        # Intra-chunk dual form: att[t, i] = exp(A_t - A_i) (C_t . B_i)
        # dt_i for i <= t.
        expo = cum[:, :, None, :] - cum[:, None, :, :]   # [B, L, L, H]
        tri = jnp.tril(jnp.ones((l, l), bool))[None, :, :, None]
        w_ti = jnp.where(tri, jnp.exp(jnp.minimum(expo, 0.0)), 0.0)
        cb = jnp.einsum("bln,bmn->blm", c_, b_)          # [B, L, L]
        att = cb[..., None] * w_ti * dt_[:, None, :, :]  # [B, L, L, H]
        y_intra = jnp.einsum("blmh,bmhp->blhp", att, u_)
        # State to chunk end.
        a_last = cum[:, -1:, :]                          # [B, 1, H]
        k_dec = jnp.exp(a_last - cum) * dt_              # [B, L, H]
        h_new = h_prev * jnp.exp(a_last[:, 0])[:, :, None, None] + \
            jnp.einsum("blh,blhp,bln->bhpn", k_dec, u_, b_)
        return h_new, y_cross + y_intra

    h_final, ys = jax.lax.scan(body, h0, (uc, bc, cc, dtc, ac))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, p)
    return y, h_final


def ssm_apply(p, x, cfg, compute_dtype=jnp.bfloat16,
              return_state: bool = False):
    """Full-sequence SSM head. x [B, S, D] -> [B, S, D] (optionally also the
    final state for prefill->decode handoff)."""
    b, s, d = x.shape
    u, z, bmat, cmat, dt, log_decay = _inputs(p, x, compute_dtype)
    pad = (-s) % CHUNK
    if pad:
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        log_decay = jnp.pad(log_decay, ((0, 0), (0, pad), (0, 0)))
    y, h_final = _chunk_scan(u, bmat, cmat, dt, log_decay)
    if pad:
        y = y[:, :s]
    y = y + p["D"][None, None] * u[:, :s].astype(jnp.float32)
    y = (y.astype(compute_dtype) * jax.nn.silu(z)).reshape(b, s, d)
    y = constrain(y, "batch", None, "embed")
    out = layers.dense(p["w_out"], y, compute_dtype)
    if return_state:
        return out, h_final
    return out


def ssm_decode_step(p, x_t, cfg, state, compute_dtype=jnp.bfloat16):
    """One-token step. state {'h': [B, H, P, N] f32}."""
    b, _, d = x_t.shape
    u, z, bmat, cmat, dt, log_decay = _inputs(p, x_t, compute_dtype)
    u_, b_, c_ = (t.astype(jnp.float32)[:, 0] for t in (u, bmat, cmat))
    dt_, a_ = dt[:, 0], log_decay[:, 0]
    h_prev = state["h"]
    h_new = h_prev * jnp.exp(a_)[..., None, None] + \
        jnp.einsum("bh,bhp,bn->bhpn", dt_, u_, b_)
    y = jnp.einsum("bhpn,bn->bhp", h_new, c_) + p["D"][None] * u_
    y = (y.astype(compute_dtype) * jax.nn.silu(z[:, 0])).reshape(b, 1, d)
    return layers.dense(p["w_out"], y, compute_dtype), {"h": h_new}
