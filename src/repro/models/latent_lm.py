"""LatentLM: bits-back coding over token *sequences* with any backbone.

This is the paper's technique applied to the assigned LM architectures
(DESIGN.md section 4): a per-sequence continuous latent

    y ~ N(0, I_Z),   q(y|s) = N(mu(s), diag(sigma^2(s))),
    p(s|y) = prod_t backbone(tok_t | prefix(y), tok_<t)

where ``prefix(y)`` maps the latent to ``n_prefix`` soft tokens prepended
to the sequence. Chaining across sequences works exactly as the paper's
Table 1: pop y from Q (bits back), push tokens under p(s|y), push y under
the max-entropy-discretized prior.

When per-sequence structure exists (regimes, topics, styles), the latent
captures it and -ELBO < plain LM cross-entropy: bits-back then wins over
direct LM-ANS coding - measured in benchmarks/latent_lm_gain.py.

The posterior encoder is a pooled-embedding MLP (cheap; the backbone is
the expensive decoder side, as in the paper's VAE where encoder and
decoder are symmetric small MLPs).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro import codecs
from repro.core import ans, discretize, lm_codec
from repro.core.codec import FnCodec
from repro.core.distributions import FactoredCategorical
from repro.models import layers, transformer


@dataclasses.dataclass(frozen=True)
class LatentLMConfig:
    backbone: Any                 # an ArchConfig
    latent_dim: int = 16
    n_prefix: int = 2
    enc_hidden: int = 128
    lat_bits: int = 10
    precision: int = 16

    @property
    def seq_precision(self) -> int:
        return self.precision


def init(key: jax.Array, cfg: LatentLMConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 5)
    bb = transformer.init(ks[0], cfg.backbone)
    d = cfg.backbone.d_model
    return {
        "backbone": bb,
        "enc_h": layers.dense_init(ks[1], d, cfg.enc_hidden, bias=True),
        "enc_mu": layers.dense_init(ks[2], cfg.enc_hidden, cfg.latent_dim,
                                    bias=True),
        "enc_logvar": layers.dense_init(ks[3], cfg.enc_hidden,
                                        cfg.latent_dim, bias=True),
        "prefix": layers.dense_init(ks[4], cfg.latent_dim,
                                    (cfg.n_prefix, d), bias=True),
    }


def encode_posterior(params, cfg: LatentLMConfig, tokens: jnp.ndarray
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens [B, N] -> (mu, sigma) [B, Z]."""
    emb = layers.embed_apply(params["backbone"]["embed"], tokens,
                             jnp.float32)
    pooled = jnp.mean(emb, axis=1)
    h = jax.nn.tanh(layers.dense(params["enc_h"], pooled, jnp.float32))
    mu = layers.dense(params["enc_mu"], h, jnp.float32)
    logvar = jnp.clip(layers.dense(params["enc_logvar"], h, jnp.float32),
                      -10.0, 10.0)
    return mu, jnp.exp(0.5 * logvar)


def _decoder_embeds(params, cfg: LatentLMConfig, y: jnp.ndarray,
                    tokens_in: jnp.ndarray) -> jnp.ndarray:
    """[prefix(y); embed(tokens_in)] -> [B, P + N, D]."""
    pref = layers.dense(params["prefix"], y.astype(jnp.float32),
                        jnp.float32)                       # [B, P, D]
    emb = layers.embed_apply(params["backbone"]["embed"], tokens_in,
                             jnp.float32)
    return jnp.concatenate([pref, emb.astype(jnp.float32)], axis=1)


def decoder_logits(params, cfg: LatentLMConfig, y: jnp.ndarray,
                   tokens: jnp.ndarray) -> jnp.ndarray:
    """Teacher-forced logits: position P-1+t predicts tokens[:, t]."""
    b, n = tokens.shape
    inp = jnp.concatenate(
        [jnp.zeros((b, 1), tokens.dtype), tokens[:, :-1]], axis=1)
    embeds = _decoder_embeds(params, cfg, y, inp)
    logits, _ = transformer.forward(params["backbone"], cfg.backbone,
                                    embeds=embeds)
    p = cfg.n_prefix
    # Input layout: [pref_0..pref_{P-1}, BOS, tok_0..tok_{N-2}]; the
    # distribution of tok_t is the output at input index P+t.
    return logits[:, p:p + n]


def elbo(params, cfg: LatentLMConfig, key: jax.Array,
         tokens: jnp.ndarray) -> jnp.ndarray:
    """Per-sequence ELBO (nats). -ELBO == expected bits-back length."""
    mu, sigma = encode_posterior(params, cfg, tokens)
    eps = jax.random.normal(key, mu.shape)
    y = mu + sigma * eps
    logits = decoder_logits(params, cfg, y, tokens).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    recon = jnp.sum(jnp.take_along_axis(
        logp, tokens[..., None].astype(jnp.int32), axis=-1)[..., 0], -1)
    kl = 0.5 * jnp.sum(mu ** 2 + sigma ** 2 - 1.0
                       - 2.0 * jnp.log(sigma), axis=-1)
    return recon - kl


def loss(params, cfg: LatentLMConfig, key: jax.Array,
         tokens: jnp.ndarray) -> Tuple[jnp.ndarray, Dict]:
    el = elbo(params, cfg, key, tokens)
    l = -jnp.mean(el)
    n = tokens.shape[-1]
    return l, {"bits_per_token": l / (n * jnp.log(2.0))}


# ---------------------------------------------------------------------------
# BB-ANS codec over sequences (paper Table 1, with s = a whole sequence)
# ---------------------------------------------------------------------------

def make_bb_codec(params, cfg: LatentLMConfig, seq_len: int
                  ) -> codecs.BBANS:
    """The LatentLM as a composable ``codecs.BBANS`` combinator.

    Prior and posterior over the per-sequence latent are jittable
    ``Repeat`` chains of leaf codecs; the likelihood drives the shared
    compiled decode step from Python (lm_codec determinism contract), so
    chain this codec with ``codecs.Chained(..., scan=False)``.
    """
    z = cfg.latent_dim

    def posterior(s):
        mu, sigma = encode_posterior(params, cfg, s)
        return codecs.Repeat(
            lambda d: codecs.DiscretizedGaussian(
                mu[:, d], sigma[:, d], cfg.lat_bits, cfg.precision),
            z)

    def _collect_logits(y, s):
        """Step the shared compiled decoder graph (lm_codec determinism
        contract): prefix soft tokens, BOS, then teacher-forced tokens."""
        b = s.shape[0]
        bb_cfg = cfg.backbone
        step = lm_codec.jitted_decode_step_embeds(bb_cfg)
        state = transformer.init_decode_state(
            bb_cfg, b, max_len=cfg.n_prefix + seq_len)
        pref = layers.dense(params["prefix"], y.astype(jnp.float32),
                            jnp.float32)
        logits = None
        for pi in range(cfg.n_prefix):
            logits, state = step(params["backbone"], x=pref[:, pi:pi + 1],
                                 state=state)
        emb_bos = layers.embed_apply(params["backbone"]["embed"],
                                     jnp.zeros((b, 1), jnp.int32),
                                     jnp.float32)
        logits, state = step(params["backbone"], x=emb_bos, state=state)
        collected = [logits[:, 0].astype(jnp.float32)]
        for t in range(seq_len - 1):
            emb = layers.embed_apply(params["backbone"]["embed"],
                                     s[:, t:t + 1], jnp.float32)
            logits, state = step(params["backbone"], x=emb, state=state)
            collected.append(logits[:, 0].astype(jnp.float32))
        return collected

    def _likelihood_push(stack, idx, s):
        y = discretize.bucket_centre(idx, cfg.lat_bits)
        logits = _collect_logits(y, s)
        push = lm_codec._jitted_push(cfg.precision)
        for t in reversed(range(seq_len)):
            stack = push(stack, logits[t], s[:, t])
        return stack

    def _likelihood_pop(stack, idx):
        y = discretize.bucket_centre(idx, cfg.lat_bits)
        b = idx.shape[0]
        bb_cfg = cfg.backbone
        step = lm_codec.jitted_decode_step_embeds(bb_cfg)
        pop = lm_codec._jitted_pop(cfg.precision)
        state = transformer.init_decode_state(
            bb_cfg, b, max_len=cfg.n_prefix + seq_len)
        pref = layers.dense(params["prefix"], y.astype(jnp.float32),
                            jnp.float32)
        logits = None
        for pi in range(cfg.n_prefix):
            logits, state = step(params["backbone"], x=pref[:, pi:pi + 1],
                                 state=state)
        emb_bos = layers.embed_apply(params["backbone"]["embed"],
                                     jnp.zeros((b, 1), jnp.int32),
                                     jnp.float32)
        logits, state = step(params["backbone"], x=emb_bos, state=state)
        out = []
        for i in range(seq_len):
            stack, sym = pop(stack, logits[:, 0].astype(jnp.float32))
            out.append(sym)
            if i < seq_len - 1:
                emb = layers.embed_apply(params["backbone"]["embed"],
                                         sym[:, None].astype(jnp.int32),
                                         jnp.float32)
                logits, state = step(params["backbone"], x=emb,
                                     state=state)
        return stack, jnp.stack(out, axis=1)

    def likelihood(idx):
        return FnCodec(
            lambda stack, s: _likelihood_push(stack, idx, s),
            lambda stack: _likelihood_pop(stack, idx))

    prior = codecs.Repeat(
        lambda d: codecs.Uniform(cfg.lat_bits, cfg.precision), z)
    return codecs.BBANS(prior=prior, likelihood=likelihood,
                        posterior=posterior)




