"""Hierarchical ResNet-VAE with a Bit-Swap codec path (HiLLoC-style).

The model is the concrete realization of the paper's closing remark -
that BB-ANS "could be used to achieve substantial improvements in
compression rate" given a better generative model - along the path
mapped by Bit-Swap (Kingma, Abbeel & Ho, 2019) and HiLLoC (Townsend,
Bird, Kunze & Barber, 2020): an L-level *Markov* latent hierarchy

    x <- z_1 <- z_2 <- ... <- z_L

with fully convolutional residual encoder/decoder blocks, so one set of
parameters codes images of **any** (even) height and width.

Structure (all stages fully convolutional, SAME padding):

  * inference (bottom-up):  q(z_1|x) = stem conv (stride 2) + resblocks;
    q(z_l|z_{l-1}) for l > 1 = resblocks at the latent resolution.
  * generative (top-down):  p(z_{l-1}|z_l) = resblocks; p(x|z_1) =
    resblocks + stride-2 transpose conv back to pixel resolution;
    p(z_L) = N(0, I).

Every latent lives on a [H/2, W/2, z_ch] grid; all conditionals are
diagonal Gaussians, so each level reuses the paper's max-entropy
discretization (``core.discretize``): latents are carried as bucket
indices under the N(0,1) grid, posteriors AND intermediate likelihoods
are coded with ``codecs.DiscretizedGaussian`` over that same grid - the
"dynamic discretization" of Bit-Swap, one fixed grid, per-layer dynamic
(mu, sigma). The decode-side bucket search is the exact computation the
``kernels/bucketize`` Pallas kernel implements (bit-parity tested in
``tests/test_kernels.py``); pass ``use_bucketize_kernel=True`` to
``make_bitswap_codec`` to route posterior decodes through it.

``make_bitswap_codec`` assembles the whole thing into a
``codecs.BitSwap`` combinator: the interleaved pop/push schedule bounds
the transient clean-bit demand by ONE layer's posterior instead of the
sum over layers (the Bit-Swap advantage; measured by
``benchmarks/hvae_rate.py``).

Pure-functional like ``models.vae``: ``init`` / ``elbo`` / ``loss`` plus
the codec builder; params are plain dicts.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro import codecs
from repro.codecs import quantize
from repro.core import discretize

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class HVAEConfig:
    """Shape-free model spec: no image size anywhere (HiLLoC's point)."""

    levels: int = 2          # L >= 1 latent levels
    in_channels: int = 1
    ch: int = 32             # hidden feature channels
    z_ch: int = 4            # latent channels per level
    n_res: int = 1           # residual blocks per stage
    likelihood: str = "bernoulli"   # or "beta_binomial"
    # Coding parameters (same trade as models.vae: 10-bit buckets inside
    # 16-bit coder precision keep the prior-smearing term < 2%).
    lat_bits: int = 10
    precision: int = 16
    obs_precision: int = 16

    @property
    def obs_params_per_pixel(self) -> int:
        return 1 if self.likelihood == "bernoulli" else 2

    def latent_shape(self, hw: Tuple[int, int]) -> Tuple[int, int, int]:
        """Latent grid for an H x W image: (H/2, W/2, z_ch)."""
        h, w = hw
        if h % 2 or w % 2:
            raise ValueError(
                f"hvae: image dims must be even (got {h}x{w}); pad with "
                "data.images.collate")
        return h // 2, w // 2, self.z_ch


# ---------------------------------------------------------------------------
# layers (pure functions over param dicts)
# ---------------------------------------------------------------------------

def _conv_init(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    w = jax.random.normal(key, (kh, kw, cin, cout)) * jnp.sqrt(2.0 / fan_in)
    return {"w": w.astype(jnp.float32),
            "b": jnp.zeros((cout,), jnp.float32)}


def _conv(p, x, stride: int = 1):
    """NHWC 3x3 (or stored-size) conv, SAME padding."""
    out = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return out + p["b"]


def _deconv(p, x, stride: int = 2):
    """NHWC transpose conv, SAME padding: exact x`stride` upsample."""
    out = jax.lax.conv_transpose(
        x, p["w"], strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return out + p["b"]


def _resblock_init(key, ch):
    k1, k2 = jax.random.split(key)
    return {"c1": _conv_init(k1, 3, 3, ch, ch),
            "c2": _conv_init(k2, 3, 3, ch, ch)}


def _resblock(p, x):
    h = _conv(p["c1"], jax.nn.relu(x))
    h = _conv(p["c2"], jax.nn.relu(h))
    return x + h


def _stage_init(key, cin, ch, cout, n_res):
    """conv in -> n_res resblocks -> conv head (2 params/output dim)."""
    keys = jax.random.split(key, n_res + 2)
    return {
        "in": _conv_init(keys[0], 3, 3, cin, ch),
        "res": [_resblock_init(keys[1 + i], ch) for i in range(n_res)],
        "head": _conv_init(keys[-1], 3, 3, ch, cout),
    }


def _stage(p, x):
    h = _conv(p["in"], x)
    for rp in p["res"]:
        h = _resblock(rp, h)
    return _conv(p["head"], jax.nn.relu(h))


def init(key: jax.Array, cfg: HVAEConfig) -> Params:
    """Initialize all stages; the param tree is image-size independent."""
    keys = jax.random.split(key, cfg.levels + 5)
    params: Params = {
        # q(z_1|x): stride-2 stem then a stage at latent resolution.
        "enc_stem": _conv_init(keys[0], 3, 3, cfg.in_channels, cfg.ch),
        "q1": _stage_init(keys[1], cfg.ch, cfg.ch, 2 * cfg.z_ch, cfg.n_res),
        # p(x|z_1): stage + stride-2 transpose conv + obs head.
        "p_obs": {
            "stage": _stage_init(keys[2], cfg.z_ch, cfg.ch, cfg.ch,
                                 cfg.n_res),
            "up": _conv_init(keys[3], 3, 3, cfg.ch, cfg.ch),
            "out": _conv_init(
                keys[4], 3, 3, cfg.ch,
                cfg.in_channels * cfg.obs_params_per_pixel),
        },
    }
    for l in range(2, cfg.levels + 1):
        kq, kp = jax.random.split(keys[3 + l])
        # q(z_l | z_{l-1}) and p(z_{l-1} | z_l), both at latent resolution.
        params[f"q{l}"] = _stage_init(kq, cfg.z_ch, cfg.ch, 2 * cfg.z_ch,
                                      cfg.n_res)
        params[f"p{l}"] = _stage_init(kp, cfg.z_ch, cfg.ch, 2 * cfg.z_ch,
                                      cfg.n_res)
    return params


# ---------------------------------------------------------------------------
# conditionals
# ---------------------------------------------------------------------------

def _split_mu_sigma(out):
    mu, logvar = jnp.split(out, 2, axis=-1)
    return mu, jnp.exp(0.5 * jnp.clip(logvar, -10.0, 10.0))


def _norm_input(cfg: HVAEConfig, x: jnp.ndarray) -> jnp.ndarray:
    scale = 1.0 if cfg.likelihood == "bernoulli" else 255.0
    x = x.astype(jnp.float32) / scale
    return x[..., None] if x.ndim == 3 else x


def infer_z1(params: Params, cfg: HVAEConfig, x: jnp.ndarray):
    """x int[lanes, H, W] -> q(z_1|x) = (mu, sigma) [lanes, H/2, W/2, z_ch]."""
    h = _conv(params["enc_stem"], _norm_input(cfg, x), stride=2)
    return _split_mu_sigma(_stage(params["q1"], jax.nn.relu(h)))


def infer_up(params: Params, cfg: HVAEConfig, level: int,
             z_prev: jnp.ndarray):
    """q(z_level | z_{level-1}) from z_{level-1} *values* (level >= 2)."""
    return _split_mu_sigma(_stage(params[f"q{level}"], z_prev))


def gen_down(params: Params, cfg: HVAEConfig, level: int, z: jnp.ndarray):
    """p(z_{level-1} | z_level) from z_level values (level >= 2)."""
    return _split_mu_sigma(_stage(params[f"p{level}"], z))


def decode_obs(params: Params, cfg: HVAEConfig, z1: jnp.ndarray):
    """z_1 values [lanes, h, w, z_ch] -> obs params [lanes, H, W, ...].

    bernoulli: logits [lanes, H, W]; beta_binomial: positive (alpha,
    beta) [lanes, H, W, 2].
    """
    p = params["p_obs"]
    h = _stage(p["stage"], z1)
    h = _deconv(p["up"], jax.nn.relu(h), stride=2)
    out = _conv(p["out"], jax.nn.relu(h))
    if cfg.likelihood == "bernoulli":
        return out[..., 0]
    return jax.nn.softplus(out) + 1e-4


def obs_log_prob(cfg: HVAEConfig, obs_params: jnp.ndarray,
                 x: jnp.ndarray) -> jnp.ndarray:
    """Sum log p(x|z_1) over pixels -> float[lanes]."""
    xf = x.astype(jnp.float32)
    if cfg.likelihood == "bernoulli":
        lp = xf * jax.nn.log_sigmoid(obs_params) \
            + (1.0 - xf) * jax.nn.log_sigmoid(-obs_params)
        return lp.sum(axis=(1, 2))
    from repro.core.distributions import beta_binomial_log_pmf
    lp = beta_binomial_log_pmf(xf, 255, obs_params[..., 0],
                               obs_params[..., 1])
    return lp.sum(axis=(1, 2))


def _gauss_logpdf(z, mu, sigma):
    return (-0.5 * ((z - mu) / sigma) ** 2 - jnp.log(sigma)
            - 0.5 * jnp.log(2.0 * jnp.pi))


# ---------------------------------------------------------------------------
# training objective
# ---------------------------------------------------------------------------

def elbo(params: Params, cfg: HVAEConfig, key: jax.Array,
         x: jnp.ndarray) -> jnp.ndarray:
    """Per-example ELBO in nats, float[lanes]; -ELBO == expected Bit-Swap
    message length (up to the bounded discretization penalty)."""
    zs: List[jnp.ndarray] = []
    logq = 0.0
    mu, sigma = infer_z1(params, cfg, x)
    for level in range(1, cfg.levels + 1):
        key, sub = jax.random.split(key)
        z = mu + sigma * jax.random.normal(sub, mu.shape)
        logq = logq + _gauss_logpdf(z, mu, sigma).sum(axis=(1, 2, 3))
        zs.append(z)
        if level < cfg.levels:
            mu, sigma = infer_up(params, cfg, level + 1, z)

    logp = obs_log_prob(cfg, decode_obs(params, cfg, zs[0]), x)
    for level in range(2, cfg.levels + 1):
        mu, sigma = gen_down(params, cfg, level, zs[level - 1])
        logp = logp + _gauss_logpdf(zs[level - 2], mu,
                                    sigma).sum(axis=(1, 2, 3))
    logp = logp + _gauss_logpdf(zs[-1], 0.0, 1.0).sum(axis=(1, 2, 3))
    return logp - logq


def elbo_bits_per_dim(params: Params, cfg: HVAEConfig, key: jax.Array,
                      x: jnp.ndarray) -> jnp.ndarray:
    n_dims = x.shape[1] * x.shape[2]
    return -jnp.mean(elbo(params, cfg, key, x)) / (n_dims * jnp.log(2.0))


def loss(params: Params, cfg: HVAEConfig, key: jax.Array,
         x: jnp.ndarray) -> jnp.ndarray:
    return -jnp.mean(elbo(params, cfg, key, x))


# ---------------------------------------------------------------------------
# Bit-Swap codec (the tentpole: hierarchy -> codecs.BitSwap)
# ---------------------------------------------------------------------------

def _gaussian_grid_codec(cfg: HVAEConfig, mu: jnp.ndarray,
                         sigma: jnp.ndarray, use_kernel: bool):
    """Code a whole latent grid as flat bucket indices [lanes, n].

    One ``DiscretizedGaussian`` per position over the shared max-entropy
    N(0,1) grid - the per-layer *dynamic* discretization: the grid is
    fixed, (mu, sigma) change with the conditioning context.
    """
    lanes = mu.shape[0]
    mu_f = mu.reshape(lanes, -1)
    sg_f = sigma.reshape(lanes, -1)
    n = mu_f.shape[1]
    if use_kernel:
        return codecs.Repeat(
            lambda d: KernelDiscretizedGaussian(
                mu_f[:, d], sg_f[:, d], cfg.lat_bits, cfg.precision), n,
            scan=False)
    return codecs.Repeat(
        lambda d: codecs.DiscretizedGaussian(
            mu_f[:, d], sg_f[:, d], cfg.lat_bits, cfg.precision), n)


@dataclasses.dataclass(frozen=True)
class KernelDiscretizedGaussian(codecs.DiscretizedGaussian):
    """``DiscretizedGaussian`` with the decode-side bucket search routed
    through the fused Pallas ``kernels/bucketize`` kernel.

    Push is inherited (the ordinary pointwise-CDF encode); pop asks the
    kernel for (idx, start, freq) in one fused pass. Kernel and
    pure-JAX bisection are bit-identical (``tests/test_kernels.py``),
    so the two leaves interoperate on the same wire bytes.
    """

    def pop(self, stack):
        from repro.core import ans
        from repro.kernels.bucketize import ops as bucketize_ops
        slot = ans.peek(stack, self.precision)
        idx, start, freq = bucketize_ops.bucketize(
            slot, self.mu, self.sigma, self.bits, self.precision)
        return ans.pop_update(stack, start, freq, self.precision), idx


def _centres(cfg: HVAEConfig, idx: jnp.ndarray,
             lat_hw: Tuple[int, int, int]) -> jnp.ndarray:
    """Flat bucket indices [lanes, n] -> latent values [lanes, h, w, c]."""
    vals = discretize.bucket_centre(idx, cfg.lat_bits)
    return vals.reshape((idx.shape[0],) + lat_hw)


def make_bitswap_codec(params: Params, cfg: HVAEConfig,
                       hw: Tuple[int, int], *,
                       use_bucketize_kernel: bool = False,
                       compiled: bool = False) -> codecs.Codec:
    """The HVAE as a ``codecs.BitSwap`` combinator for H x W images.

    The networks are fully convolutional, so ONE trained ``params`` set
    yields a codec for *any* even image shape - call this once per shape
    (``serve.CodecEngine`` memoizes that for you). Image symbols are
    int[lanes, H, W]; latent symbols are flat bucket indices
    int32[lanes, (H/2) * (W/2) * z_ch].

    ``compiled=True`` lowers the whole Bit-Swap schedule through
    ``codecs.compile``: every latent grid and the observation layer
    code through fused multi-step kernels inside one jit program per
    direction - byte-identical wire, no per-position dispatch
    (benchmarks/codec_compile.py measures the speedup).

    Use with the container or the BBX2 stream:

        codec = make_bitswap_codec(params, cfg, (28, 28))
        blob = codecs.compress(codecs.Chained(codec, n), data,
                               lanes=lanes, seed=0)
        wire = stream.encode_stream(codec, data, lanes=lanes,
                                    block_symbols=8, init_chunks=32)
    """
    h, w = hw
    lat_hw = cfg.latent_shape(hw)
    uk = use_bucketize_kernel

    def obs_codec(obs_params):
        lanes = obs_params.shape[0]
        if cfg.likelihood == "bernoulli":
            logits = obs_params.reshape(lanes, -1)
            return codecs.Shaped(
                codecs.Repeat(
                    lambda d: codecs.Bernoulli(logits[:, d],
                                               cfg.obs_precision),
                    h * w), (h, w))
        ab = obs_params.reshape(lanes, -1, 2)
        return codecs.Shaped(
            codecs.Repeat(
                lambda d: codecs.BetaBinomial(
                    ab[:, d, 0], ab[:, d, 1], 255, cfg.obs_precision),
                h * w), (h, w))

    def posterior1(x):
        mu, sigma = infer_z1(params, cfg, x)
        return _gaussian_grid_codec(cfg, mu, sigma, uk)

    def likelihood1(z1_idx):
        z1 = _centres(cfg, z1_idx, lat_hw)
        return obs_codec(decode_obs(params, cfg, z1))

    layers = [(posterior1, likelihood1)]
    for level in range(2, cfg.levels + 1):
        def posterior_l(z_prev_idx, _level=level):
            z_prev = _centres(cfg, z_prev_idx, lat_hw)
            mu, sigma = infer_up(params, cfg, _level, z_prev)
            return _gaussian_grid_codec(cfg, mu, sigma, uk)

        def likelihood_l(z_idx, _level=level):
            z = _centres(cfg, z_idx, lat_hw)
            mu, sigma = gen_down(params, cfg, _level, z)
            return _gaussian_grid_codec(cfg, mu, sigma, uk)

        layers.append((posterior_l, likelihood_l))

    n_lat = lat_hw[0] * lat_hw[1] * lat_hw[2]
    prior = codecs.Repeat(
        lambda d: codecs.Uniform(cfg.lat_bits, cfg.precision), n_lat)
    swap = codecs.BitSwap(prior=prior, layers=tuple(layers))
    return codecs.compile(swap) if compiled else swap


# ---------------------------------------------------------------------------
# fixed-point (quantized) inference + fused Bit-Swap codec
# ---------------------------------------------------------------------------

def quantize_model(params: Params, cfg: HVAEConfig,
                   qcfg: quantize.QuantConfig = quantize.QuantConfig()
                   ) -> Params:
    """Quantize every conv stage to the fixed-point format."""
    del cfg
    return quantize.quantize_params(params, qcfg)


def _stage_q(pq: Params, x_q: jnp.ndarray,
             qcfg: quantize.QuantConfig) -> jnp.ndarray:
    """Fixed-point twin of ``_stage``: int conv in -> int resblocks ->
    relu -> int conv head."""
    h = quantize.conv_q(pq["in"], x_q, qcfg)
    for rp in pq["res"]:
        r = quantize.conv_q(rp["c1"], quantize.relu_q(h), qcfg)
        r = quantize.conv_q(rp["c2"], quantize.relu_q(r), qcfg)
        h = jnp.clip(h + r, -qcfg.act_clip, qcfg.act_clip)
    return quantize.conv_q(pq["head"], quantize.relu_q(h), qcfg)


def _gaussian_head_q(out_q: jnp.ndarray, qcfg: quantize.QuantConfig
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Split a quantized stage head into flat (mu, sigma) [lanes, n]."""
    mu_q, lv_q = jnp.split(out_q, 2, axis=-1)
    mu, sigma = quantize.gaussian_head(mu_q, lv_q, qcfg)
    lanes = mu.shape[0]
    return mu.reshape(lanes, -1), sigma.reshape(lanes, -1)


def infer_z1_q(qparams: Params, cfg: HVAEConfig,
               qcfg: quantize.QuantConfig, x: jnp.ndarray
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fixed-point q(z_1|x): x int[lanes, H, W] -> flat (mu, sigma)."""
    x_q = quantize.quantize_input(x, qcfg)[..., None]
    h = quantize.conv_q(qparams["enc_stem"], x_q, qcfg, stride=2)
    out = _stage_q(qparams["q1"], quantize.relu_q(h), qcfg)
    return _gaussian_head_q(out, qcfg)


def _latent_grid_q(cfg: HVAEConfig, qcfg: quantize.QuantConfig,
                   idx: jnp.ndarray,
                   lat_hw: Tuple[int, int, int]) -> jnp.ndarray:
    """Flat bucket indices [lanes, n] -> int32 Q(act) [lanes, h, w, c]."""
    vals = quantize.latent_centres_q(idx, cfg.lat_bits, qcfg)
    return vals.reshape((idx.shape[0],) + lat_hw)


def stage_gaussian_q(qparams: Params, cfg: HVAEConfig,
                     qcfg: quantize.QuantConfig, name: str,
                     idx: jnp.ndarray, lat_hw: Tuple[int, int, int]
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fixed-point q(z_l|z_{l-1}) / p(z_{l-1}|z_l) from bucket indices."""
    z_q = _latent_grid_q(cfg, qcfg, idx, lat_hw)
    return _gaussian_head_q(_stage_q(qparams[name], z_q, qcfg), qcfg)


def decode_obs_freq1_q(qparams: Params, cfg: HVAEConfig,
                       qcfg: quantize.QuantConfig, idx: jnp.ndarray,
                       lat_hw: Tuple[int, int, int]) -> jnp.ndarray:
    """Fixed-point p(x|z_1) (bernoulli): bucket indices -> uint32
    [lanes, H*W] fixed-point freq of pixel = 1."""
    p = qparams["p_obs"]
    z_q = _latent_grid_q(cfg, qcfg, idx, lat_hw)
    h = _stage_q(p["stage"], z_q, qcfg)
    h = quantize.deconv_q(p["up"], quantize.relu_q(h), qcfg, stride=2)
    logit_q = quantize.conv_q(p["out"], quantize.relu_q(h), qcfg)[..., 0]
    f1 = quantize.bernoulli_head(logit_q, cfg.obs_precision, qcfg)
    return f1.reshape(f1.shape[0], -1)


def make_bitswap_codec_q(params: Params, cfg: HVAEConfig,
                         hw: Tuple[int, int], *,
                         qcfg: quantize.QuantConfig =
                         quantize.QuantConfig(),
                         compiled: bool = False) -> codecs.Codec:
    """The *quantized* HVAE as a Bit-Swap combinator (HiLLoC-style).

    Same layer schedule as ``make_bitswap_codec``, but every network
    evaluation is fixed point (``codecs.quantize``) and wrapped in
    ``FixedPointFn`` markers, so ``compiled=True`` fuses the whole
    interleaved pop/push schedule - convolutions included - into ONE
    jit program per direction. Wire bytes: identical interpreted vs
    fused; different from the float model (coarser net).
    """
    if cfg.likelihood != "bernoulli":
        raise ValueError(
            "make_bitswap_codec_q: fixed-point inference supports the "
            f"bernoulli likelihood only (got {cfg.likelihood!r})")
    h, w = hw
    lat_hw = cfg.latent_shape(hw)
    n_lat = lat_hw[0] * lat_hw[1] * lat_hw[2]
    qp = quantize_model(params, cfg, qcfg)

    def gauss_fn(fn):
        return quantize.FixedPointFn(fn, "gaussian", n_lat, cfg.lat_bits,
                                     cfg.precision)

    posterior1 = gauss_fn(lambda x: infer_z1_q(qp, cfg, qcfg, x))
    likelihood1 = quantize.FixedPointFn(
        lambda idx: decode_obs_freq1_q(qp, cfg, qcfg, idx, lat_hw),
        "bernoulli", h * w, 0, cfg.obs_precision, (h, w))
    layers = [(posterior1, likelihood1)]
    for level in range(2, cfg.levels + 1):
        layers.append((
            gauss_fn(lambda idx, _l=level: stage_gaussian_q(
                qp, cfg, qcfg, f"q{_l}", idx, lat_hw)),
            gauss_fn(lambda idx, _l=level: stage_gaussian_q(
                qp, cfg, qcfg, f"p{_l}", idx, lat_hw)),
        ))

    prior = codecs.Repeat(
        lambda d: codecs.Uniform(cfg.lat_bits, cfg.precision), n_lat)
    swap = codecs.BitSwap(prior=prior, layers=tuple(layers))
    return codecs.compile(swap) if compiled else swap


def codec_family(params: Params, cfg: HVAEConfig, **kwargs):
    """``shape -> Codec`` factory for ``serve.CodecEngine``: the "one
    model, any image size" entry point."""
    def make(shape: Tuple[int, ...]) -> codecs.BitSwap:
        if len(shape) != 2:
            raise ValueError(
                f"hvae: expected per-lane symbols [H, W], got shape "
                f"{shape}")
        return make_bitswap_codec(params, cfg, (shape[0], shape[1]),
                                  **kwargs)
    return make
