"""Native AdamW with gradient clipping - pytree-based, pjit-friendly.

No optax in this environment; this is a minimal-but-complete production
optimizer: bias-corrected Adam moments, decoupled weight decay, global-norm
clipping, and a state layout that shards identically to the params (so FSDP
policies apply transparently to optimizer state).
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray  # int32 scalar
    mu: Any            # first moment, like params
    nu: Any            # second moment, like params


class AdamW(NamedTuple):
    learning_rate: Callable[[jnp.ndarray], jnp.ndarray]
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: Optional[float] = 1.0

    def init(self, params: Any) -> AdamWState:
        zeros = lambda p: jnp.zeros_like(p)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree_util.tree_map(zeros, params),
                          nu=jax.tree_util.tree_map(zeros, params))

    def update(self, grads: Any, state: AdamWState,
               params: Any) -> Tuple[Any, AdamWState]:
        step = state.step + 1
        if self.clip_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
        b1, b2 = self.b1, self.b2
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * (g * g), state.nu, grads)
        t = step.astype(jnp.float32)
        mu_hat_scale = 1.0 / (1 - b1 ** t)
        nu_hat_scale = 1.0 / (1 - b2 ** t)
        lr = self.learning_rate(step)

        def upd(p, m, v):
            u = (m * mu_hat_scale) / (
                jnp.sqrt(v * nu_hat_scale) + self.eps)
            if self.weight_decay:
                u = u + self.weight_decay * p
            return (p - lr * u).astype(p.dtype)

        new_params = jax.tree_util.tree_map(upd, params, mu, nu)
        return new_params, AdamWState(step=step, mu=mu, nu=nu)


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def constant_lr(value: float) -> Callable:
    return lambda step: jnp.asarray(value, jnp.float32)


def cosine_lr(peak: float, warmup: int, total: int,
              floor: float = 0.0) -> Callable:
    def fn(step):
        s = step.astype(jnp.float32)
        warm = peak * s / max(warmup, 1)
        frac = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(s < warmup, warm, cos)

    return fn
