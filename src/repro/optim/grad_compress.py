"""Error-feedback int8 gradient compression with ANS entropy coding.

The paper's coder, reused as a *distributed-training transport codec*
(DESIGN.md section 5): before a cross-pod (DCN) reduce, gradients are

  1. summed with the carried error-feedback residual,
  2. quantized to int8 with a per-tensor scale,
  3. entropy-coded with the lane-vectorized rANS coder under an empirical
     (shared, per-step) symbol table - quantized gradients are strongly
     peaked around 0, so ANS gets well under 8 bits/param,
  4. the quantization error is carried to the next step (error feedback
     keeps SGD/Adam convergence, Karimireddy et al. 2019).

``simulate_transport`` runs compress->code->decode->decompress and returns
the exact wire bits, so the trainer can report true compression ratios.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import ans


class CompressState(NamedTuple):
    error: Any  # pytree like grads: carried quantization residual


def init_state(grads_like: Any) -> CompressState:
    return CompressState(error=jax.tree_util.tree_map(
        lambda g: jnp.zeros_like(g, jnp.float32), grads_like))


def quantize(g: jnp.ndarray, err: jnp.ndarray
             ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """-> (q int8, scale f32 scalar, new_error f32)."""
    x = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    new_err = x - q.astype(jnp.float32) * scale
    return q, scale, new_err


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_grads(grads: Any, state: CompressState
                   ) -> Tuple[Any, CompressState]:
    """Pytree-wise quantize/dequantize with error feedback.

    Returns (transported grads, new state). This is what the trainer
    applies; the entropy-coded wire size is measured separately by
    ``measure_wire_bits`` (keeps the hot path free of the coder).
    """
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(state.error)
    outs = [quantize(g, e) for g, e in zip(flat_g, flat_e)]
    deq = [dequantize(q, s).astype(g.dtype)
           for (q, s, _), g in zip(outs, flat_g)]
    new_err = [o[2] for o in outs]
    return (tdef.unflatten(deq),
            CompressState(error=tdef.unflatten(new_err)))


def measure_wire_bits(grads: Any, state: CompressState,
                      lanes: int = 16, sample_cap: int = 1 << 16
                      ) -> Tuple[float, float]:
    """Entropy-code the int8 stream with rANS; return (bits_total,
    bits_per_param). Large tensors are subsampled (deterministically) for
    the measurement; the ratio extrapolates since coding is i.i.d. over a
    shared table."""
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(state.error)
    total_bits = 0.0
    total_params = 0
    for g, e in zip(flat_g, flat_e):
        q, _, _ = quantize(g, e)
        sym = (q.reshape(-1).astype(jnp.int32) + 127)  # 0..254
        n = sym.shape[0]
        total_params += n
        take = min(n, sample_cap)
        sym = sym[:take]
        # Shared empirical table (would be transmitted: 255 * 2 bytes).
        hist = jnp.bincount(sym, length=255).astype(jnp.float32)
        probs = (hist + 0.5) / (jnp.sum(hist) + 0.5 * 255)
        table = ans.probs_to_starts(
            jnp.tile(probs[None], (lanes, 1)), ans.DEFAULT_PRECISION)
        pad = (-take) % lanes
        sym = jnp.pad(sym, (0, pad), constant_values=127)
        sym = sym.reshape(-1, lanes)
        stack = ans.make_stack(lanes, sym.shape[0] + 8)
        b0 = float(ans.stack_content_bits(stack))

        def body(i, st):
            return ans.push_with_table(st, table, sym[i],
                                       ans.DEFAULT_PRECISION)

        stack = jax.lax.fori_loop(0, sym.shape[0], body, stack)
        bits = float(ans.stack_content_bits(stack)) - b0
        total_bits += bits * (n / take) + 255 * 16  # + table cost
    return total_bits, total_bits / max(total_params, 1)
