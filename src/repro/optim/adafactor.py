"""Adafactor (Shazeer & Stern, 2018) - factored second moments.

The memory-frugal optimizer used for the largest MoE configs (DESIGN.md
section 5): second-moment statistics are factored into row/column running
means for every rank>=2 leaf, so optimizer state is O(rows + cols) instead
of O(rows * cols). No first moment by default (beta1=0), relative step
sizes, update clipping - the production T5/PaLM recipe.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdafactorState(NamedTuple):
    step: jnp.ndarray
    vr: Any   # row stats   (leaf.shape[:-1]) for rank>=2 else full
    vc: Any   # col stats   (leaf.shape[:-2] + (last,)) for rank>=2 else ()
    mu: Any   # first moment if beta1 else ()


class Adafactor(NamedTuple):
    learning_rate: Callable[[jnp.ndarray], jnp.ndarray]
    beta1: float = 0.0
    decay_exponent: float = 0.8
    eps1: float = 1e-30
    eps2: float = 1e-3
    clip_threshold: float = 1.0
    weight_decay: float = 0.0

    def init(self, params: Any) -> AdafactorState:
        def vr_like(p):
            return jnp.zeros(p.shape[:-1], jnp.float32) if p.ndim >= 2 \
                else jnp.zeros(p.shape, jnp.float32)

        def vc_like(p):
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32) \
                if p.ndim >= 2 else jnp.zeros((1,), jnp.float32)

        mu = jax.tree_util.tree_map(jnp.zeros_like, params) \
            if self.beta1 else jax.tree_util.tree_map(
                lambda p: jnp.zeros((1,), jnp.float32), params)
        return AdafactorState(
            step=jnp.zeros((), jnp.int32),
            vr=jax.tree_util.tree_map(vr_like, params),
            vc=jax.tree_util.tree_map(vc_like, params),
            mu=mu)

    #: Leaves above this many elements get the chunked (two-pass) update:
    #: f32 temporaries per chunk instead of per leaf. Exact same math.
    CHUNK_THRESHOLD = 1 << 24

    def update(self, grads: Any, state: AdafactorState,
               params: Any) -> Tuple[Any, AdafactorState]:
        step = state.step + 1
        t = step.astype(jnp.float32)
        beta2 = 1.0 - t ** -self.decay_exponent
        lr = self.learning_rate(step)

        def stats_and_u(g, vr, vc):
            g = g.astype(jnp.float32)
            g2 = g * g + self.eps1
            vr_new = beta2 * vr + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc_new = beta2 * vc + (1 - beta2) * jnp.mean(g2, axis=-2)
            row = vr_new / jnp.mean(vr_new, axis=-1, keepdims=True)
            u = g * jax.lax.rsqrt(row)[..., None] * \
                jax.lax.rsqrt(vc_new)[..., None, :]
            return vr_new, vc_new, u

        def finish(p, u, rms_u, m):
            u = u / jnp.maximum(1.0, rms_u / self.clip_threshold)
            if self.beta1:
                m = self.beta1 * m + (1 - self.beta1) * u
                u = m
            scale = lr * jnp.maximum(
                self.eps2,
                jnp.sqrt(jnp.mean(p.astype(jnp.float32) ** 2)))
            new_p = p.astype(jnp.float32) - scale * u
            if self.weight_decay:
                new_p = new_p - lr * self.weight_decay * \
                    p.astype(jnp.float32)
            return new_p.astype(p.dtype), m

        def upd(p, g, vr, vc, m):
            if p.ndim < 2:
                g32 = g.astype(jnp.float32)
                vr_new = beta2 * vr + (1 - beta2) * (g32 * g32 + self.eps1)
                u = g32 * jax.lax.rsqrt(vr_new)
                rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-30)
                new_p, m = finish(p, u, rms_u, m)
                return new_p, vr_new, vc, m
            if p.ndim >= 3 and p.size > self.CHUNK_THRESHOLD \
                    and not self.beta1:  # chunked path assumes no momentum
                # Two-pass chunked update over axis 0 (layer/expert stack):
                # pass 1 computes the factored stats + sum(u^2) per chunk,
                # pass 2 recomputes u and applies clip/step. Identical math
                # to the unchunked path (all reductions are over the last
                # two axes or global), f32 peak shrinks by the stack size.
                vr_new, vc_new, u2 = jax.lax.map(
                    lambda args: (lambda v: (v[0], v[1],
                                             jnp.sum(v[2] * v[2])))(
                        stats_and_u(*args)), (g, vr, vc))
                rms_u = jnp.sqrt(jnp.sum(u2) / float(p.size) + 1e-30)
                scale = lr * jnp.maximum(
                    self.eps2,
                    jnp.sqrt(jnp.mean(p.astype(jnp.float32) ** 2)))

                def apply_chunk(args):
                    p_i, g_i, vr_i, vc_i = args
                    _, _, u = stats_and_u(g_i, vr_i, vc_i)
                    u = u / jnp.maximum(1.0, rms_u / self.clip_threshold)
                    new_p = p_i.astype(jnp.float32) - scale * u
                    if self.weight_decay:
                        new_p = new_p - lr * self.weight_decay * \
                            p_i.astype(jnp.float32)
                    return new_p.astype(p_i.dtype)

                new_p = jax.lax.map(apply_chunk, (p, g, vr, vc))
                return new_p, vr_new, vc_new, m
            vr_new, vc_new, u = stats_and_u(g, vr, vc)
            rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            new_p, m = finish(p, u, rms_u, m)
            return new_p, vr_new, vc_new, m

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_vr = tdef.flatten_up_to(state.vr)
        flat_vc = tdef.flatten_up_to(state.vc)
        flat_mu = tdef.flatten_up_to(state.mu)
        outs = [upd(p, g, vr, vc, m) for p, g, vr, vc, m in
                zip(flat_p, flat_g, flat_vr, flat_vc, flat_mu)]
        new_p = tdef.unflatten([o[0] for o in outs])
        new_vr = tdef.unflatten([o[1] for o in outs])
        new_vc = tdef.unflatten([o[2] for o in outs])
        new_mu = tdef.unflatten([o[3] for o in outs]) if self.beta1 \
            else state.mu
        return new_p, AdafactorState(step=step, vr=new_vr, vc=new_vc,
                                     mu=new_mu)
